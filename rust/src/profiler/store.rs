//! Persistent, versioned, on-disk profile store.
//!
//! Profiling is the expensive phase of the paper's pipeline — every
//! setting is simulated repeatedly before regression modeling can begin —
//! and PR 1's in-memory executor cache only helps within one process.
//! This store spills that cache to disk so *any* CLI invocation
//! (`profile`, `fig3`, `fig4`, `table1`, `e2e`, `serve`, scheduler
//! what-ifs) warm-starts from every prior session on the machine.
//!
//! # On-disk layout
//!
//! A store is a directory:
//!
//! ```text
//! store/
//!   index.bin               compacted records (binary v3, atomically replaced)
//!   seg-<pid>-<n>-<t>.bin   append-only binary segment, one per writing session
//!   seg-....bin.lock        liveness lock while that segment is open
//!   compact.lock            held briefly while rewriting the index
//!   index.jsonl             legacy v1/v2 index — still read, migrated on the
//!   seg-....jsonl           fly, and rewritten as v3 by the next compaction
//! ```
//!
//! Store format **v3** is binary: a file is an 8-byte header (magic
//! `MRTS` + little-endian version) followed by length-prefixed records
//! (see [`encode_record_bin`]).  Every `u64` and `f64` travels as its raw
//! little-endian bits, so stored values are the same bit-identical rep
//! results the executor produces — which is what makes warm runs
//! byte-identical to cold ones — and parsing a million-record store is a
//! linear scan, not a million JSON documents.  The previous JSONL formats
//! (v1 from PR 2, v2 from PR 3; see [`encode_record`]) are still decoded
//! on read and never orphaned.
//!
//! # Size cap and eviction
//!
//! [`ProfileStore::open_capped`] bounds the compacted index
//! (`--store-max-mb` / `MRTUNER_STORE_MAX_MB` on the CLI).  Records carry
//! a **touch** — the generation at which they were last written or
//! answered a lookup — and when a compaction would exceed the cap, the
//! least-recently-used records are dropped first.  Capped sessions
//! persist their lookup recency at flush (deduplicating record frames
//! the next compaction folds); uncapped sessions bump it in memory only,
//! so a plain warm run stays write-free.  Repetitions on the paper plane
//! (input 8 GB, block 64 MB) are **pinned**: they are the online
//! trainer's training data and are never evicted, whatever the cap.
//!
//! # Concurrency and crash safety
//!
//! * Every writing session appends to its **own** uniquely-named segment
//!   file, so two processes sharing a store directory never interleave
//!   writes.
//! * A live segment is marked by a `.lock` file (created before the
//!   segment, removed on drop); compaction merges a locked segment's
//!   flushed records but never deletes the file under a live writer.
//!   Locks carry the writer's pid — a lock whose process is gone
//!   (crashed session) is reclaimed together with its segment.
//! * On open, segments are folded into `index.bin` via
//!   write-to-temp + atomic rename, guarded by `compact.lock` taken
//!   *before* the directory is read (`create_new`, so only one process
//!   compacts at a time; losers just skip the pass, and a stale lock
//!   left by a crashed compactor is reclaimed after ten minutes).
//! * Corruption is tolerated, never fatal: an unreadable file or a
//!   truncated/garbled record is counted, logged to stderr, and skipped.
//!   Files or lines of a *newer* store-format version than
//!   [`STORE_FORMAT_VERSION`] are skipped too, and their segment is
//!   preserved for whichever build understands it; v1/v2 JSONL data is
//!   migrated on read and rewritten as v3 by compaction.

use std::collections::{BTreeSet, HashMap};
use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use crate::apps::AppId;
use crate::mr::RepOutcome;
use crate::util::bytes::{hex_u64, parse_hex_u64};
use crate::util::json::{parse, Json};

/// Store format version; bump when the record schema changes.
///
/// * **v1** (PR 2): JSONL; 2-parameter keys `(cluster, app, m, r, rep,
///   seed)` holding a bare execution time.
/// * **v2** (PR 3): JSONL; keys additionally carry `input_gb`/`block_mb`
///   (the extended 4-parameter sweep axes) and records hold a
///   [`RepOutcome`] — total time plus total CPU seconds.
/// * **v3** (PR 5): binary segments and index — length-prefixed records
///   behind an `MRTS` file header, raw little-endian bit round-trip for
///   every `u64`/`f64`, plus a persisted last-hit **touch** generation
///   that drives size-capped LRU eviction.
///
/// v1/v2 JSONL lines are **migrated on read**: they decode into v3 keys
/// (v1 lands at the paper-default input/block values with the CPU figure
/// absent), so existing stores keep answering, and the next compaction
/// rewrites everything as v3 binary.  Readers skip (and preserve) files
/// or records of any *newer* version.
pub const STORE_FORMAT_VERSION: u32 = 3;

/// Version written by the legacy JSONL record codec ([`encode_record`]).
const JSONL_RECORD_VERSION: u32 = 2;

const INDEX_FILE: &str = "index.bin";
const LEGACY_INDEX_FILE: &str = "index.jsonl";
const COMPACT_LOCK: &str = "compact.lock";

/// Magic prefix of every binary (v3) store file.
const BIN_MAGIC: [u8; 4] = *b"MRTS";
/// Binary file header: magic + little-endian u32 format version.
const BIN_HEADER_LEN: usize = 8;
/// Sanity bound on a record's length prefix; anything larger is framing
/// corruption (a real record is well under 128 bytes).
const MAX_RECORD_LEN: usize = 4096;

/// A `compact.lock` older than this is assumed to be the debris of a
/// crashed process (a compaction pass takes well under a second) and is
/// reclaimed, so one crash can never disable compaction forever.
const STALE_COMPACT_LOCK: Duration = Duration::from_secs(600);

/// Distinguishes session segments from everything else in the directory.
const SEGMENT_PREFIX: &str = "seg-";
const SEGMENT_SUFFIX: &str = ".bin";
const LEGACY_SEGMENT_SUFFIX: &str = ".jsonl";

/// Makes segment names unique when one process opens several stores (or
/// several executors share a directory) within one clock tick.
static SEG_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Identity of one simulated repetition — the executor's cache key made
/// persistent.  The cluster fingerprint keeps times from one hardware
/// model from ever answering for another; `base_seed` keys the profiling
/// session so distinct sessions never alias.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StoreKey {
    /// Fingerprint of every simulation-relevant cluster field.
    pub cluster: u64,
    /// Application profiled.
    pub app: AppId,
    /// Number of map tasks (the paper's first parameter).
    pub num_mappers: u32,
    /// Number of reduce tasks (the paper's second parameter).
    pub num_reducers: u32,
    /// Input size in GB — the extended sweep's third parameter — as raw
    /// `f64` bits (`f64` has no `Eq`/`Hash`; bits keep the key exact).
    /// The paper's own setup is [`StoreKey::PAPER_INPUT_GB`].
    pub input_gb_bits: u64,
    /// HDFS block size in MB — the extended sweep's fourth parameter.
    /// The paper's own setup is [`StoreKey::PAPER_BLOCK_MB`].
    pub block_mb: u32,
    /// Repetition index within the profiling session.
    pub rep: u32,
    /// Profiling-session seed.
    pub base_seed: u64,
}

impl StoreKey {
    /// Input size of the paper's testbed (`JobConfig::paper_default`) —
    /// where 2-parameter keys, and migrated v1 records, live in the 4-D
    /// parameter space.
    pub const PAPER_INPUT_GB: f64 = 8.0;
    /// HDFS block size of the paper's testbed.
    pub const PAPER_BLOCK_MB: u32 = 64;

    /// Input size in GB.
    pub fn input_gb(&self) -> f64 {
        f64::from_bits(self.input_gb_bits)
    }

    /// Whether this key lies on the **paper plane** (paper-default input
    /// and block size).  Paper-plane repetitions feed the online trainer
    /// ([`crate::coordinator::Trainer`]) and are therefore *pinned*:
    /// size-capped eviction never drops them.
    pub fn is_paper_plane(&self) -> bool {
        self.input_gb_bits == StoreKey::PAPER_INPUT_GB.to_bits()
            && self.block_mb == StoreKey::PAPER_BLOCK_MB
    }
}

/// Why a record line failed to decode.
#[derive(Clone, Debug, PartialEq)]
pub enum RecordError {
    /// The line is a record of a store-format version this build cannot
    /// read (newer than [`STORE_FORMAT_VERSION`], or 0/garbage).
    StaleVersion(u64),
    /// The line is not a valid record at all (truncated write, garbage).
    Corrupt(String),
}

// ------------------------------------------------- legacy JSONL codec

/// Serialize one `(key, per-rep outcome)` record as a **legacy v2 JSON
/// line** — the format PR 2/PR 3 builds wrote.  Kept for store-upgrade
/// tests and tooling; the store itself writes the binary v3 codec
/// ([`encode_record_bin`]) since PR 5.
pub fn encode_record(key: &StoreKey, outcome: &RepOutcome) -> String {
    // "t"/"cpu" are redundant human-readable copies; the hex "bits"
    // fields are authoritative.  "cbits"/"cpu" are omitted when the CPU
    // figure is unknown (v1-migrated data).
    let mut pairs = vec![
        ("v", Json::Num(JSONL_RECORD_VERSION as f64)),
        ("cluster", Json::Str(hex_u64(key.cluster))),
        ("app", Json::Str(key.app.name().to_string())),
        ("m", Json::Num(key.num_mappers as f64)),
        ("r", Json::Num(key.num_reducers as f64)),
        ("igb", Json::Str(hex_u64(key.input_gb_bits))),
        ("blk", Json::Num(key.block_mb as f64)),
        ("rep", Json::Num(key.rep as f64)),
        ("seed", Json::Str(hex_u64(key.base_seed))),
        ("bits", Json::Str(hex_u64(outcome.time_s.to_bits()))),
        ("t", Json::Num(outcome.time_s)),
    ];
    if let Some(cpu) = outcome.cpu_s {
        pairs.push(("cbits", Json::Str(hex_u64(cpu.to_bits()))));
        pairs.push(("cpu", Json::Num(cpu)));
    }
    Json::obj(pairs).to_string()
}

/// Decode a legacy JSONL record line written by [`encode_record`] (v2)
/// or by the v1 store, returning the key, the outcome, and the version
/// the line was written under.
///
/// v1 lines are migrated on the fly: their key lands at the paper-default
/// input/block values (the only point v1 could describe) and the CPU
/// figure is absent — they are never orphaned, and compaction rewrites
/// them as v3 binary.
pub fn decode_record(
    line: &str,
) -> Result<(StoreKey, RepOutcome, u32), RecordError> {
    let v = parse(line).map_err(RecordError::Corrupt)?;
    let ver = v.req_u64("v").map_err(RecordError::Corrupt)?;
    let decode = |legacy_v1: bool| -> Result<(StoreKey, RepOutcome), String> {
        let (input_gb_bits, block_mb) = if legacy_v1 {
            (StoreKey::PAPER_INPUT_GB.to_bits(), StoreKey::PAPER_BLOCK_MB)
        } else {
            (parse_hex_u64(v.req_str("igb")?)?, v.req_u32("blk")?)
        };
        let key = StoreKey {
            cluster: parse_hex_u64(v.req_str("cluster")?)?,
            app: AppId::parse(v.req_str("app")?)?,
            num_mappers: v.req_u32("m")?,
            num_reducers: v.req_u32("r")?,
            input_gb_bits,
            block_mb,
            rep: v.req_u32("rep")?,
            base_seed: parse_hex_u64(v.req_str("seed")?)?,
        };
        let time_s = f64::from_bits(parse_hex_u64(v.req_str("bits")?)?);
        let cpu_s = match v.get("cbits") {
            None => None,
            Some(j) => Some(f64::from_bits(parse_hex_u64(
                j.as_str().ok_or("cbits: expected hex string")?,
            )?)),
        };
        Ok((key, RepOutcome { time_s, cpu_s }))
    };
    match ver {
        2 => decode(false)
            .map(|(k, o)| (k, o, 2))
            .map_err(RecordError::Corrupt),
        1 => decode(true)
            .map(|(k, o)| (k, o, 1))
            .map_err(RecordError::Corrupt),
        other => Err(RecordError::StaleVersion(other)),
    }
}

// ------------------------------------------------------ binary v3 codec

/// Exact encoded payload size of one binary record (no length prefix).
fn payload_len(key: &StoreKey, outcome: &RepOutcome) -> usize {
    // 5 u64s + 4 u32s + app length byte + app name + cpu flag (+ cpu bits)
    5 * 8
        + 4 * 4
        + 1
        + key.app.name().len()
        + 1
        + if outcome.cpu_s.is_some() { 8 } else { 0 }
}

/// Exact on-disk size of one framed binary record (length prefix
/// included) — what the size-cap accounting sums.
fn frame_len(key: &StoreKey, outcome: &RepOutcome) -> usize {
    4 + payload_len(key, outcome)
}

/// The 8-byte header every binary store file starts with.
fn bin_header() -> [u8; BIN_HEADER_LEN] {
    let mut h = [0u8; BIN_HEADER_LEN];
    h[..4].copy_from_slice(&BIN_MAGIC);
    h[4..].copy_from_slice(&STORE_FORMAT_VERSION.to_le_bytes());
    h
}

/// Append one framed binary record to `out`.
fn encode_record_bin_into(
    key: &StoreKey,
    outcome: &RepOutcome,
    touch: u64,
    out: &mut Vec<u8>,
) {
    let len = payload_len(key, outcome);
    debug_assert!(len <= MAX_RECORD_LEN);
    out.extend_from_slice(&(len as u32).to_le_bytes());
    let start = out.len();
    out.extend_from_slice(&key.cluster.to_le_bytes());
    out.extend_from_slice(&key.base_seed.to_le_bytes());
    out.extend_from_slice(&key.input_gb_bits.to_le_bytes());
    out.extend_from_slice(&outcome.time_s.to_bits().to_le_bytes());
    out.extend_from_slice(&touch.to_le_bytes());
    out.extend_from_slice(&key.num_mappers.to_le_bytes());
    out.extend_from_slice(&key.num_reducers.to_le_bytes());
    out.extend_from_slice(&key.block_mb.to_le_bytes());
    out.extend_from_slice(&key.rep.to_le_bytes());
    let name = key.app.name().as_bytes();
    out.push(name.len() as u8);
    out.extend_from_slice(name);
    match outcome.cpu_s {
        Some(cpu) => {
            out.push(1);
            out.extend_from_slice(&cpu.to_bits().to_le_bytes());
        }
        None => out.push(0),
    }
    debug_assert_eq!(out.len() - start, len);
}

/// Serialize one record as a length-prefixed **binary v3** frame: the
/// format the store's segments and index are written in since PR 5.
/// Every `u64`/`f64` is stored as raw little-endian bits, so arbitrary
/// bit patterns — NaN payloads included — round-trip exactly.  `touch`
/// is the record's last-hit generation (drives LRU eviction under a
/// size cap).
pub fn encode_record_bin(
    key: &StoreKey,
    outcome: &RepOutcome,
    touch: u64,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(frame_len(key, outcome));
    encode_record_bin_into(key, outcome, touch, &mut out);
    out
}

/// Bounds-checked little-endian reader over one binary payload.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .i
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| "binary record truncated".to_string())?;
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

/// Decode one binary payload (the bytes after a record's length prefix).
fn decode_payload(b: &[u8]) -> Result<(StoreKey, RepOutcome, u64), String> {
    let mut c = Cursor { b, i: 0 };
    let cluster = c.u64()?;
    let base_seed = c.u64()?;
    let input_gb_bits = c.u64()?;
    let time_bits = c.u64()?;
    let touch = c.u64()?;
    let num_mappers = c.u32()?;
    let num_reducers = c.u32()?;
    let block_mb = c.u32()?;
    let rep = c.u32()?;
    let app_len = c.u8()? as usize;
    let app_bytes = c.take(app_len)?;
    let app = AppId::parse(
        std::str::from_utf8(app_bytes)
            .map_err(|_| "binary record: app name not UTF-8".to_string())?,
    )?;
    let cpu_s = match c.u8()? {
        0 => None,
        1 => Some(f64::from_bits(c.u64()?)),
        other => return Err(format!("binary record: bad cpu flag {other}")),
    };
    if c.i != b.len() {
        return Err("binary record: trailing payload bytes".into());
    }
    Ok((
        StoreKey {
            cluster,
            app,
            num_mappers,
            num_reducers,
            input_gb_bits,
            block_mb,
            rep,
            base_seed,
        },
        RepOutcome { time_s: f64::from_bits(time_bits), cpu_s },
        touch,
    ))
}

/// Decode one framed binary record produced by [`encode_record_bin`]
/// from the front of `bytes`.  Returns the record, its touch generation,
/// and the total bytes consumed (prefix + payload), so callers can walk
/// a concatenated record stream.
pub fn decode_record_bin(
    bytes: &[u8],
) -> Result<(StoreKey, RepOutcome, u64, usize), String> {
    if bytes.len() < 4 {
        return Err("binary record truncated (length prefix)".into());
    }
    let len =
        u32::from_le_bytes(bytes[0..4].try_into().expect("4 bytes")) as usize;
    if len == 0 || len > MAX_RECORD_LEN {
        return Err(format!("binary record: implausible length {len}"));
    }
    let end = 4 + len;
    if bytes.len() < end {
        return Err("binary record truncated (payload)".into());
    }
    let (key, outcome, touch) = decode_payload(&bytes[4..end])?;
    Ok((key, outcome, touch, end))
}

/// Strictly decode every record in one store file — binary v3 or legacy
/// JSONL — returning each record with the version it was stored under
/// (the file version for binary, the per-line `"v"` for JSONL).  Any
/// corruption is an error: this is the store-inspection/tooling path,
/// not the fault-tolerant load path.
pub fn read_file_records(
    path: &Path,
) -> Result<Vec<(StoreKey, RepOutcome, u32)>, String> {
    let bytes =
        fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let mut out = Vec::new();
    if bytes.is_empty() {
        return Ok(out);
    }
    if bytes.len() >= 4 && bytes[..4] == BIN_MAGIC {
        if bytes.len() < BIN_HEADER_LEN {
            return Err("truncated binary store header".into());
        }
        let ver = u32::from_le_bytes(
            bytes[4..BIN_HEADER_LEN].try_into().expect("4 bytes"),
        );
        if ver != STORE_FORMAT_VERSION {
            return Err(format!("unsupported binary store version {ver}"));
        }
        let mut i = BIN_HEADER_LEN;
        while i < bytes.len() {
            let (key, outcome, _touch, used) = decode_record_bin(&bytes[i..])?;
            out.push((key, outcome, ver));
            i += used;
        }
    } else {
        let text = std::str::from_utf8(&bytes)
            .map_err(|_| format!("{}: not UTF-8", path.display()))?;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (key, outcome, ver) =
                decode_record(line).map_err(|e| format!("{e:?}"))?;
            out.push((key, outcome, ver));
        }
    }
    Ok(out)
}

// ----------------------------------------------------------- the store

/// What `open` saw on disk, plus the live pending-write count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Distinct records currently loaded.
    pub entries: usize,
    /// Segment files present when the store was opened.
    pub segments_seen: usize,
    /// Segments folded into the index (and deleted) by the open pass.
    pub merged_segments: usize,
    /// Files that could not be read at all (skipped, logged).
    pub corrupt_segments: usize,
    /// Undecodable lines/records inside otherwise readable files.
    pub corrupt_lines: usize,
    /// Lines — or whole binary files — of a *newer* store-format version
    /// (skipped, preserved).
    pub stale_lines: usize,
    /// Legacy JSONL (v1/v2) lines migrated on read into v3 records
    /// (rewritten as binary by the next compaction).
    pub migrated_lines: usize,
    /// Records dropped by size-capped LRU eviction during this open's
    /// compaction (never paper-plane reps — those are pinned).
    pub evicted: usize,
    /// Whether the open pass rewrote the index.
    pub compacted: bool,
}

impl std::fmt::Display for StoreStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "entries={} segments_seen={} merged={} corrupt_segments={} \
             corrupt_lines={} stale_lines={} migrated={} evicted={} \
             compacted={}",
            self.entries,
            self.segments_seen,
            self.merged_segments,
            self.corrupt_segments,
            self.corrupt_lines,
            self.stale_lines,
            self.migrated_lines,
            self.evicted,
            self.compacted
        )
    }
}

struct SegmentWriter {
    file: fs::File,
    lock: PathBuf,
}

impl SegmentWriter {
    /// Create a fresh uniquely-named binary segment (header written
    /// immediately), taking its liveness lock *first* so a concurrent
    /// compaction never deletes it underneath us.
    fn create(dir: &Path) -> Result<SegmentWriter, String> {
        let nonce = SEG_COUNTER.fetch_add(1, Ordering::Relaxed);
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let name = format!(
            "{SEGMENT_PREFIX}{:08x}-{:04x}-{}{SEGMENT_SUFFIX}",
            std::process::id(),
            nonce,
            hex_u64(nanos)
        );
        let path = dir.join(&name);
        let lock = lock_path(&path);
        let mut lf = OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&lock)
            .map_err(|e| format!("store: create lock {}: {e}", lock.display()))?;
        let _ = writeln!(lf, "{}", std::process::id());
        let mut file = match OpenOptions::new()
            .append(true)
            .create_new(true)
            .open(&path)
        {
            Ok(f) => f,
            Err(e) => {
                let _ = fs::remove_file(&lock);
                return Err(format!(
                    "store: create segment {}: {e}",
                    path.display()
                ));
            }
        };
        if let Err(e) = file.write_all(&bin_header()) {
            let _ = fs::remove_file(&lock);
            return Err(format!(
                "store: write segment header {}: {e}",
                path.display()
            ));
        }
        Ok(SegmentWriter { file, lock })
    }
}

impl Drop for SegmentWriter {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.lock);
    }
}

/// One resident record: the outcome plus its last-hit **touch**
/// generation (persisted in v3 records; 0 for data migrated from JSONL
/// stores, which therefore evicts first under a cap).
#[derive(Clone, Copy, Debug, PartialEq)]
struct StoredRep {
    outcome: RepOutcome,
    touch: u64,
}

struct Inner {
    /// Key → stored record (held as the very `f64`s that were
    /// decoded/produced, so every bit round-trips by construction).
    entries: HashMap<StoreKey, StoredRep>,
    /// Key of every record this store instance has accepted, in
    /// acceptance order: the on-disk records found at open (sorted, so
    /// the order is deterministic), then every `put`/`refresh`
    /// insertion.  `journal.len()` is the store's **generation**;
    /// consumers tail the store by remembering the generation they last
    /// read ([`ProfileStore::read_since`]).  Keys only — the outcome
    /// always lives in `entries` (which never shrinks), so the journal
    /// does not double the store's resident memory.  An upgraded record
    /// (CPU figure added) appears twice; both occurrences resolve to
    /// the live (upgraded) outcome.
    journal: Vec<StoreKey>,
    /// Encoded binary frames not yet appended to this session's segment.
    dirty: Vec<u8>,
    /// Records represented in `dirty` (the `pending()` count).
    dirty_count: usize,
    /// Keys whose touch generation changed since the last flush (lookup
    /// hits and re-puts of known values).  Flush appends a fresh frame
    /// per touched key so recency survives the process — that is what
    /// makes cross-session LRU eviction meaningful.  Only populated
    /// when the store was opened with a size cap: an uncapped warm run
    /// must stay write-free, not rewrite its whole hit set (the frames
    /// have no consumer without eviction).  BTreeSet so the flush order
    /// (and therefore segment bytes) is deterministic.
    touched: BTreeSet<StoreKey>,
    /// Whether lookup recency is persisted at flush (capped opens).
    persist_touches: bool,
    /// Monotonic touch clock, seeded from the largest touch on disk.
    clock: u64,
    /// Lazily created on first flush, so sessions with nothing to
    /// persist (reads without a cap, inspection) leave no file behind.
    writer: Option<SegmentWriter>,
}

/// The persistent profile store: an in-memory view of every record on
/// disk, plus an append-only writer for this session's new results.
///
/// The [`super::CampaignExecutor`] reads through it on cache misses and
/// writes freshly simulated reps back; `flush` runs at campaign
/// boundaries and on drop.  All methods take `&self` and are safe to call
/// from the executor's worker threads.
///
/// ```
/// use mrtuner::apps::AppId;
/// use mrtuner::mr::RepOutcome;
/// use mrtuner::profiler::{ProfileStore, StoreKey};
///
/// let dir = std::env::temp_dir()
///     .join(format!("mrtuner_doc_store_{}", std::process::id()));
/// let _ = std::fs::remove_dir_all(&dir);
///
/// let key = StoreKey {
///     cluster: 0xC0FFEE,
///     app: AppId::WordCount,
///     num_mappers: 20,
///     num_reducers: 5,
///     input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
///     block_mb: StoreKey::PAPER_BLOCK_MB,
///     rep: 0,
///     base_seed: 42,
/// };
/// {
///     let store = ProfileStore::open(&dir).unwrap();
///     store.put(key, RepOutcome::full(1523.25, 96.5));
///     store.flush().unwrap();
/// }
/// // A later session — any process on the machine — warm-starts from it.
/// let store = ProfileStore::open(&dir).unwrap();
/// assert_eq!(store.get(&key), Some(RepOutcome::full(1523.25, 96.5)));
/// drop(store);
/// # let _ = std::fs::remove_dir_all(&dir);
/// ```
pub struct ProfileStore {
    dir: PathBuf,
    inner: Mutex<Inner>,
    stats: StoreStats,
    /// Per-file refresh bookkeeping: store file name → length as of the
    /// last successful ingest of that file.  [`ProfileStore::refresh`]
    /// re-parses only files whose length changed (segments are
    /// append-only; the index is replaced wholesale by compaction), so
    /// an idle poll is a directory stat and a steady-state poll costs
    /// the changed files, not the whole store.
    refresh_state: Mutex<HashMap<String, u64>>,
}

impl ProfileStore {
    /// Open (creating if needed) the store at `dir`, folding any
    /// completed segments into the index — the compaction pass.
    pub fn open(dir: &Path) -> Result<ProfileStore, String> {
        ProfileStore::open_with(dir, true, None)
    }

    /// Open with a size cap on the compacted index, in bytes: when a
    /// compaction would exceed the cap, the least-recently-used records
    /// are evicted first (paper-plane reps are pinned and never
    /// dropped).  The CLI exposes this as `--store-max-mb` /
    /// `MRTUNER_STORE_MAX_MB`.
    pub fn open_capped(
        dir: &Path,
        max_bytes: Option<u64>,
    ) -> Result<ProfileStore, String> {
        ProfileStore::open_with(dir, true, max_bytes)
    }

    /// Open without compacting — inspection (`store stats`) and tests.
    pub fn peek(dir: &Path) -> Result<ProfileStore, String> {
        ProfileStore::open_with(dir, false, None)
    }

    fn open_with(
        dir: &Path,
        compact: bool,
        cap_bytes: Option<u64>,
    ) -> Result<ProfileStore, String> {
        fs::create_dir_all(dir)
            .map_err(|e| format!("store: create dir {}: {e}", dir.display()))?;

        // The compaction lock must be taken *before* reading: compaction
        // is a read-modify-write of the whole directory, and rewriting
        // the index from a pre-lock snapshot could overwrite a newer
        // index whose source segments are already deleted — losing data.
        let guard = if compact { CompactGuard::acquire(dir) } else { None };
        if compact && guard.is_none() {
            eprintln!("store: compaction lock busy; skipping compaction pass");
        }

        let mut scan = scan_dir(dir)?;
        let mut stats = scan.stats;
        if guard.is_some() {
            let over_cap =
                cap_bytes.is_some_and(|cap| index_bytes(&scan.entries) > cap);
            // Compaction is needed when there are segments to fold, when a
            // legacy JSONL index should be rewritten as v3, or when the
            // size cap demands eviction.
            let need =
                !scan.mergeable.is_empty() || scan.legacy_index || over_cap;
            if need {
                if scan.index_unreadable {
                    // Rewriting the index now would replace the (unreadable
                    // but possibly recoverable) old index with segment data
                    // only.  Leave everything in place for manual recovery.
                    eprintln!(
                        "store: index unreadable; compaction disabled to avoid data loss"
                    );
                } else {
                    let evicted = match cap_bytes {
                        Some(cap) => evict_to_cap(&mut scan.entries, cap),
                        None => Vec::new(),
                    };
                    match write_index(dir, &scan.entries) {
                        Ok(()) => {
                            for p in &scan.mergeable {
                                // Best-effort; also reclaim a dead writer's
                                // leftover lock so it stops shadowing opens.
                                let _ = fs::remove_file(p);
                                let _ = fs::remove_file(lock_path(p));
                            }
                            // The legacy index is folded into the binary
                            // one; drop it so it cannot resurrect records.
                            let _ =
                                fs::remove_file(dir.join(LEGACY_INDEX_FILE));
                            stats.compacted = true;
                            stats.merged_segments = scan.mergeable.len();
                            stats.evicted = evicted.len();
                            if !evicted.is_empty() {
                                eprintln!(
                                    "store: size cap: evicted {} \
                                     least-recently-used record(s)",
                                    evicted.len()
                                );
                            }
                        }
                        Err(e) => {
                            // The old index is still authoritative: put the
                            // would-be evictions back so memory keeps
                            // agreeing with disk (and evicted stays 0).
                            for (key, sr) in evicted {
                                scan.entries.insert(key, sr);
                            }
                            eprintln!("store: compaction skipped: {e}");
                        }
                    }
                }
            }
        }
        drop(guard);

        stats.entries = scan.entries.len();
        // Seed the journal with everything already on disk, sorted by key
        // so the initial generation's contents are deterministic.
        let mut journal: Vec<StoreKey> = scan.entries.keys().copied().collect();
        journal.sort();
        let clock = scan.entries.values().map(|sr| sr.touch).max().unwrap_or(0);
        Ok(ProfileStore {
            dir: dir.to_path_buf(),
            inner: Mutex::new(Inner {
                entries: scan.entries,
                journal,
                dirty: Vec::new(),
                dirty_count: 0,
                touched: BTreeSet::new(),
                persist_touches: cap_bytes.is_some(),
                clock,
                writer: None,
            }),
            stats,
            refresh_state: Mutex::new(HashMap::new()),
        })
    }

    /// Directory this store lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Stats snapshot from the open pass, with `entries` refreshed to the
    /// live count.
    pub fn stats(&self) -> StoreStats {
        let mut s = self.stats;
        s.entries = self.len();
        s
    }

    /// Stored outcome for `key`, if any prior session simulated it.  A
    /// hit bumps the record's touch generation (it was just *used*), so
    /// hot records survive size-capped eviction; on a capped open the
    /// bump is persisted at the next flush.
    pub fn get(&self, key: &StoreKey) -> Option<RepOutcome> {
        let mut guard = self.inner.lock().expect("store mutex poisoned");
        let inner = &mut *guard;
        match inner.entries.get_mut(key) {
            Some(sr) => {
                inner.clock += 1;
                sr.touch = inner.clock;
                if inner.persist_touches {
                    inner.touched.insert(*key);
                }
                Some(sr.outcome)
            }
            None => None,
        }
    }

    /// Record a freshly simulated outcome.  Buffered in memory until
    /// [`ProfileStore::flush`]; a value already on disk is not rewritten
    /// (its touch generation is bumped instead), and a CPU-less value
    /// (v1-migrated) never displaces a full one — though a full outcome
    /// *does* upgrade a CPU-less record in place.
    pub fn put(&self, key: StoreKey, outcome: RepOutcome) {
        let mut guard = self.inner.lock().expect("store mutex poisoned");
        let inner = &mut *guard;
        inner.clock += 1;
        let clock = inner.clock;
        let known = match inner.entries.get_mut(&key) {
            Some(old)
                if old.outcome.same_bits(&outcome)
                    || (old.outcome.cpu_s.is_some()
                        && outcome.cpu_s.is_none()) =>
            {
                // Re-putting a known value is a use: recency only.
                old.touch = clock;
                if inner.persist_touches {
                    inner.touched.insert(key);
                }
                true
            }
            _ => false,
        };
        if !known {
            inner.entries.insert(key, StoredRep { outcome, touch: clock });
            inner.journal.push(key);
            encode_record_bin_into(&key, &outcome, clock, &mut inner.dirty);
            inner.dirty_count += 1;
        }
    }

    /// Monotonic change counter: how many records this store instance has
    /// accepted so far (disk records found at open plus every later
    /// insertion).  A consumer that remembers the generation it last saw
    /// reads exactly the new records via [`ProfileStore::read_since`] —
    /// the change-detection contract the online trainer tails.
    pub fn generation(&self) -> u64 {
        self.inner.lock().expect("store mutex poisoned").journal.len() as u64
    }

    /// Every record accepted after `generation`, plus the generation that
    /// snapshot corresponds to (pass it back next time).  `read_since(0)`
    /// returns the whole store in deterministic order.  The stream is an
    /// upsert log: a key may repeat when its record was upgraded in place
    /// (CPU figure added) — every occurrence carries the live record, so
    /// later entries are consistent with earlier ones.
    pub fn read_since(
        &self,
        generation: u64,
    ) -> (Vec<(StoreKey, RepOutcome)>, u64) {
        let inner = self.inner.lock().expect("store mutex poisoned");
        let from = (generation as usize).min(inner.journal.len());
        let records = inner.journal[from..]
            .iter()
            .map(|k| {
                let outcome = inner
                    .entries
                    .get(k)
                    .map(|sr| sr.outcome)
                    .expect("journaled key always resident");
                (*k, outcome)
            })
            .collect();
        (records, inner.journal.len() as u64)
    }

    /// Re-scan the store directory and fold in records written by *other*
    /// sessions since this store was opened (their flushed segment
    /// records — binary v3 or legacy JSONL — and any index rewritten by
    /// their compactions).  Returns how many records were new.  Records
    /// this instance already holds are left untouched — in particular a
    /// full outcome is never displaced by a CPU-less duplicate, and by
    /// the determinism invariant equal keys carry equal values, so
    /// keeping the resident record is always sound.  This is the polling
    /// half of the trainer's profile-store-to-model loop.
    ///
    /// Polls are incremental: store files are fingerprinted by
    /// `(name, length)`, and only *changed* files are re-parsed — an
    /// idle poll is a directory stat, a steady-state poll re-reads just
    /// the growing segment(s), and the (large) index is re-read only
    /// when a compaction replaced it.  Lengths are recorded only after
    /// a file was successfully ingested, so a transient read failure
    /// can never suppress future re-scans; a torn tail record (racing a
    /// writer's flush) is skipped now and re-parsed when the file next
    /// grows, because any completed write changes the length observed
    /// *before* this read started.
    pub fn refresh(&self) -> Result<u64, String> {
        let fingerprint = dir_fingerprint(&self.dir)?;
        let changed: Vec<(String, u64)> = {
            let state =
                self.refresh_state.lock().expect("store refresh-state poisoned");
            fingerprint
                .iter()
                .filter(|(name, len)| state.get(name) != Some(len))
                .cloned()
                .collect()
        };
        if changed.is_empty() {
            return Ok(0);
        }
        // Re-parse only the changed files, tolerating (and logging)
        // corruption exactly like the open pass.
        let mut parsed: HashMap<StoreKey, StoredRep> = HashMap::new();
        let mut stats = StoreStats::default();
        let mut ingested: Vec<(String, u64)> = Vec::new();
        for (name, len) in changed {
            let path = self.dir.join(&name);
            match fs::read(&path) {
                Ok(bytes) => {
                    let _ = ingest_bytes(&path, &bytes, &mut parsed, &mut stats);
                    ingested.push((name, len));
                }
                // Deleted mid-refresh (racing compaction): its records
                // are in the rewritten index, whose length changed too.
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => eprintln!(
                    "store: refresh skipping unreadable {}: {e}",
                    path.display()
                ),
            }
        }
        let mut guard = self.inner.lock().expect("store mutex poisoned");
        let inner = &mut *guard;
        let mut fresh: Vec<(StoreKey, StoredRep)> = Vec::new();
        for (key, sr) in parsed {
            inner.clock = inner.clock.max(sr.touch);
            match inner.entries.get_mut(&key) {
                Some(old) => {
                    // Another session used this record: keep the newest
                    // recency, but never downgrade a full outcome.
                    old.touch = old.touch.max(sr.touch);
                    if old.outcome.cpu_s.is_none() && sr.outcome.cpu_s.is_some()
                    {
                        fresh.push((key, StoredRep { outcome: sr.outcome, touch: old.touch }));
                    }
                }
                None => fresh.push((key, sr)),
            }
        }
        // Sort so concurrent writers' records land in the journal in a
        // deterministic order whatever the directory scan produced.
        fresh.sort_by(|a, b| a.0.cmp(&b.0));
        let new_records = fresh.len() as u64;
        for (key, sr) in fresh {
            inner.entries.insert(key, sr);
            inner.journal.push(key);
        }
        drop(guard);
        let mut state =
            self.refresh_state.lock().expect("store refresh-state poisoned");
        // Forget files compaction removed, so the map stays bounded by
        // the live file set ...
        state.retain(|name, _| fingerprint.iter().any(|(n, _)| n == name));
        // ... and record the pre-read lengths of what was ingested (a
        // write landing mid-read makes the next poll re-read that file —
        // the safe direction).
        for (name, len) in ingested {
            state.insert(name, len);
        }
        Ok(new_records)
    }

    /// Distinct records currently held (disk + this session's new ones).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("store mutex poisoned").entries.len()
    }

    /// Whether the store holds no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Records buffered but not yet appended to this session's segment.
    pub fn pending(&self) -> usize {
        self.inner.lock().expect("store mutex poisoned").dirty_count
    }

    /// Append buffered records — new results, plus (for capped opens)
    /// recency bumps for records this session looked up — to this
    /// session's segment (created, with its liveness lock, on first
    /// flush).  Called by the executor at campaign boundaries and from
    /// `Drop`.
    pub fn flush(&self) -> Result<(), String> {
        let mut guard = self.inner.lock().expect("store mutex poisoned");
        let inner = &mut *guard;
        if inner.dirty.is_empty() && inner.touched.is_empty() {
            return Ok(());
        }
        if inner.writer.is_none() {
            inner.writer = Some(SegmentWriter::create(&self.dir)?);
        }
        let mut buf =
            Vec::with_capacity(inner.dirty.len() + 96 * inner.touched.len());
        buf.extend_from_slice(&inner.dirty);
        // Recency bumps travel as full (deduplicating) record frames; the
        // next compaction folds them and keeps the newest touch.
        for key in &inner.touched {
            if let Some(sr) = inner.entries.get(key) {
                encode_record_bin_into(key, &sr.outcome, sr.touch, &mut buf);
            }
        }
        let writer = inner.writer.as_mut().expect("writer just created");
        writer
            .file
            .write_all(&buf)
            .map_err(|e| format!("store: append failed: {e}"))?;
        writer
            .file
            .flush()
            .map_err(|e| format!("store: flush failed: {e}"))?;
        inner.dirty.clear();
        inner.dirty_count = 0;
        inner.touched.clear();
        Ok(())
    }

    /// Delete every store file under `dir` (index, segments, locks,
    /// leftover temp files — binary and legacy JSONL alike).  Returns how
    /// many files were removed; a missing directory is an empty store,
    /// not an error.
    pub fn clear(dir: &Path) -> Result<usize, String> {
        let rd = match fs::read_dir(dir) {
            Ok(rd) => rd,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(format!("store: read {}: {e}", dir.display())),
        };
        let mut removed = 0;
        for entry in rd {
            let entry = entry.map_err(|e| format!("store: read dir entry: {e}"))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let ours = name == INDEX_FILE
                || name == LEGACY_INDEX_FILE
                || name == COMPACT_LOCK
                || name.starts_with(&format!("{INDEX_FILE}.tmp-"))
                || name.starts_with(&format!("{LEGACY_INDEX_FILE}.tmp-"))
                || (name.starts_with(SEGMENT_PREFIX)
                    && (name.ends_with(SEGMENT_SUFFIX)
                        || name.ends_with(LEGACY_SEGMENT_SUFFIX)
                        || name.ends_with(&format!("{SEGMENT_SUFFIX}.lock"))
                        || name
                            .ends_with(&format!("{LEGACY_SEGMENT_SUFFIX}.lock"))));
            if ours {
                fs::remove_file(entry.path())
                    .map_err(|e| format!("store: remove {name}: {e}"))?;
                removed += 1;
            }
        }
        Ok(removed)
    }
}

impl Drop for ProfileStore {
    fn drop(&mut self) {
        if let Err(e) = self.flush() {
            eprintln!("store: flush on drop failed: {e}");
        }
        // Dropping `inner` drops the SegmentWriter, releasing its lock.
    }
}

// --------------------------------------------------- directory scanning

/// Everything one pass over the store directory learns.
struct Scan {
    entries: HashMap<StoreKey, StoredRep>,
    /// Segments safe to fold into the index and delete: readable, not
    /// held by a live writer, and free of newer-version records (legacy
    /// JSONL segments *are* mergeable — migration rewrites them as v3).
    mergeable: Vec<PathBuf>,
    stats: StoreStats,
    /// The index existed but could not be read (or belongs to a newer
    /// build) — compaction must not rewrite it from segment data alone.
    index_unreadable: bool,
    /// A readable legacy JSONL index is present: compaction should run
    /// even with no segments to fold, so the index is rewritten as v3.
    legacy_index: bool,
}

/// Read the index and every segment under `dir` into memory, tolerating
/// (and tallying) corruption.  Load order is deterministic (legacy index,
/// binary index, then segments in sorted name order), and by determinism
/// of the simulator any duplicate keys carry equal values, so later-wins
/// is harmless — with one exception handled in [`fold_entry`]: a CPU-less
/// (v1-migrated) duplicate never displaces a full outcome, whatever the
/// load order.  Duplicate touches resolve to the maximum (newest use).
fn scan_dir(dir: &Path) -> Result<Scan, String> {
    let mut scan = Scan {
        entries: HashMap::new(),
        mergeable: Vec::new(),
        stats: StoreStats::default(),
        index_unreadable: false,
        legacy_index: false,
    };
    for (name, legacy) in [(LEGACY_INDEX_FILE, true), (INDEX_FILE, false)] {
        let path = dir.join(name);
        match fs::read(&path) {
            Ok(bytes) => {
                let stale_before = scan.stats.stale_lines;
                let ok = ingest_bytes(
                    &path,
                    &bytes,
                    &mut scan.entries,
                    &mut scan.stats,
                );
                if !ok || scan.stats.stale_lines != stale_before {
                    // Unreadable, or written by a newer build: either way
                    // this open does not know the index's full contents.
                    scan.index_unreadable = true;
                } else if legacy {
                    scan.legacy_index = true;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                scan.stats.corrupt_segments += 1;
                scan.index_unreadable = true;
                eprintln!(
                    "store: skipping unreadable index {}: {e}",
                    path.display()
                );
            }
        }
    }

    for path in segment_paths(dir)? {
        scan.stats.segments_seen += 1;
        let locked = segment_is_locked(&path);
        match fs::read(&path) {
            Ok(bytes) => {
                let stale_before = scan.stats.stale_lines;
                let readable = ingest_bytes(
                    &path,
                    &bytes,
                    &mut scan.entries,
                    &mut scan.stats,
                );
                // A locked segment is still being written; one with
                // newer-version content belongs to another build.  Both
                // are merged-from but never deleted.
                if readable
                    && !locked
                    && scan.stats.stale_lines == stale_before
                {
                    scan.mergeable.push(path);
                }
            }
            // Raced with another process's compaction: the segment's
            // records are in the index that pass wrote.  Not corruption.
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                scan.stats.corrupt_segments += 1;
                eprintln!(
                    "store: skipping unreadable segment {}: {e}",
                    path.display()
                );
            }
        }
    }
    Ok(scan)
}

/// Fold one decoded record into the in-memory map: later wins, except a
/// CPU-less outcome never displaces a full one, and the touch resolves
/// to the newest (maximum) generation either side has seen.
fn fold_entry(
    entries: &mut HashMap<StoreKey, StoredRep>,
    key: StoreKey,
    rep: StoredRep,
) {
    match entries.get_mut(&key) {
        Some(old) => {
            old.touch = old.touch.max(rep.touch);
            if !(old.outcome.cpu_s.is_some() && rep.outcome.cpu_s.is_none()) {
                old.outcome = rep.outcome;
            }
        }
        None => {
            entries.insert(key, rep);
        }
    }
}

/// Fold one store file's bytes into `entries`, dispatching on format:
/// binary v3 (`MRTS` magic) or legacy JSONL.  Returns `false` when the
/// file as a whole could not be used (not UTF-8 JSONL, torn binary
/// header, or a newer binary version) — such files are never merged.
fn ingest_bytes(
    path: &Path,
    bytes: &[u8],
    entries: &mut HashMap<StoreKey, StoredRep>,
    stats: &mut StoreStats,
) -> bool {
    if bytes.is_empty() {
        return true;
    }
    if bytes.len() >= 4 && bytes[..4] == BIN_MAGIC {
        if bytes.len() < BIN_HEADER_LEN {
            // Torn header write: no records to recover.
            stats.corrupt_lines += 1;
            eprintln!(
                "store: truncated binary header in {}",
                path.display()
            );
            return true;
        }
        let ver = u32::from_le_bytes(
            bytes[4..BIN_HEADER_LEN].try_into().expect("4 bytes"),
        );
        if !(3..=STORE_FORMAT_VERSION).contains(&ver) {
            // A whole file of a newer build: skip and preserve.
            stats.stale_lines += 1;
            return true;
        }
        load_bin_records(path, bytes, entries, stats);
        true
    } else {
        match std::str::from_utf8(bytes) {
            Ok(text) => {
                load_lines(path, text, entries, stats);
                true
            }
            Err(_) => {
                stats.corrupt_segments += 1;
                eprintln!(
                    "store: skipping non-UTF-8, non-binary file {}",
                    path.display()
                );
                false
            }
        }
    }
}

/// Walk the framed records of a binary store file (header already
/// validated), tolerating corruption: a garbled payload of plausible
/// length is skipped record-by-record; a torn length prefix ends the
/// file (nothing after it can be re-synchronized).
fn load_bin_records(
    path: &Path,
    bytes: &[u8],
    entries: &mut HashMap<StoreKey, StoredRep>,
    stats: &mut StoreStats,
) {
    let mut i = BIN_HEADER_LEN;
    let mut first_bad = true;
    while i < bytes.len() {
        let Some(prefix) = bytes.get(i..i + 4) else {
            stats.corrupt_lines += 1;
            eprintln!(
                "store: truncated record tail in {}",
                path.display()
            );
            return;
        };
        let len = u32::from_le_bytes(prefix.try_into().expect("4 bytes")) as usize;
        if len == 0 || len > MAX_RECORD_LEN || i + 4 + len > bytes.len() {
            stats.corrupt_lines += 1;
            eprintln!(
                "store: truncated/garbled record tail in {}",
                path.display()
            );
            return;
        }
        match decode_payload(&bytes[i + 4..i + 4 + len]) {
            Ok((key, outcome, touch)) => {
                fold_entry(entries, key, StoredRep { outcome, touch });
            }
            Err(e) => {
                stats.corrupt_lines += 1;
                if first_bad {
                    first_bad = false;
                    eprintln!(
                        "store: skipping corrupt record(s) in {}: {e}",
                        path.display()
                    );
                }
            }
        }
        i += 4 + len;
    }
}

/// Fold every decodable JSONL line of `text` into `entries`, tallying
/// skips and migrations.  Duplicate-key resolution is [`fold_entry`]'s.
fn load_lines(
    path: &Path,
    text: &str,
    entries: &mut HashMap<StoreKey, StoredRep>,
    stats: &mut StoreStats,
) {
    let mut first_bad = true;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match decode_record(line) {
            Ok((key, outcome, ver)) => {
                if ver < STORE_FORMAT_VERSION {
                    stats.migrated_lines += 1;
                }
                // JSONL predates touch tracking: migrated records start
                // at generation 0, i.e. coldest — first out under a cap.
                fold_entry(entries, key, StoredRep { outcome, touch: 0 });
            }
            Err(RecordError::StaleVersion(_)) => stats.stale_lines += 1,
            Err(RecordError::Corrupt(e)) => {
                stats.corrupt_lines += 1;
                if first_bad {
                    first_bad = false;
                    eprintln!(
                        "store: skipping corrupt line(s) in {}: {e}",
                        path.display()
                    );
                }
            }
        }
    }
}

// --------------------------------------------- locks, paths, compaction

/// Liveness-lock path for a segment file (`<segment>.lock`).
fn lock_path(segment: &Path) -> PathBuf {
    let name = segment
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    segment.with_file_name(format!("{name}.lock"))
}

/// Whether `segment` is held by a **live** writer.  Lock files carry the
/// writer's pid; a lock whose process is gone (crashed writer) no longer
/// protects the segment, so compaction can reclaim it.  An empty or
/// garbled lock is treated as live — it may be mid-creation.
fn segment_is_locked(segment: &Path) -> bool {
    let lock = lock_path(segment);
    match fs::read_to_string(&lock) {
        Err(_) if !lock.exists() => false,
        Err(_) => true, // unreadable lock: assume live
        Ok(text) => match text.trim().parse::<u32>() {
            Ok(pid) => pid_alive(pid),
            Err(_) => true, // pid not written yet: assume live
        },
    }
}

/// Stores are per-machine (the lock protocol relies on a shared pid
/// namespace), so /proc is authoritative on Linux; elsewhere be
/// conservative and treat every lock holder as alive.
#[cfg(target_os = "linux")]
pub(crate) fn pid_alive(pid: u32) -> bool {
    Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn pid_alive(_pid: u32) -> bool {
    true
}

/// Whether `name` is a store data file (index or segment, either format).
fn is_store_file(name: &str) -> bool {
    name == INDEX_FILE
        || name == LEGACY_INDEX_FILE
        || (name.starts_with(SEGMENT_PREFIX)
            && (name.ends_with(SEGMENT_SUFFIX)
                || name.ends_with(LEGACY_SEGMENT_SUFFIX)))
}

/// `(name, length)` of every store file (index + segments) under `dir`,
/// sorted by name — the cheap change detector behind
/// [`ProfileStore::refresh`].  Segments are append-only and compaction
/// replaces whole files, so any new record changes some file's length
/// (or the file set).
fn dir_fingerprint(dir: &Path) -> Result<Vec<(String, u64)>, String> {
    let rd = fs::read_dir(dir)
        .map_err(|e| format!("store: read {}: {e}", dir.display()))?;
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("store: read dir entry: {e}"))?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if !is_store_file(&name) {
            continue;
        }
        // A file deleted mid-scan (racing compaction) counts as length 0;
        // the next pass sees the final state.
        let len = entry.metadata().map(|m| m.len()).unwrap_or(0);
        out.push((name, len));
    }
    out.sort();
    Ok(out)
}

/// All segment files under `dir` (binary and legacy), sorted by name.
fn segment_paths(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let rd = fs::read_dir(dir)
        .map_err(|e| format!("store: read {}: {e}", dir.display()))?;
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("store: read dir entry: {e}"))?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with(SEGMENT_PREFIX)
            && (name.ends_with(SEGMENT_SUFFIX)
                || name.ends_with(LEGACY_SEGMENT_SUFFIX))
        {
            out.push(entry.path());
        }
    }
    out.sort();
    Ok(out)
}

/// Exact byte size of the binary index [`write_index`] would produce.
fn index_bytes(entries: &HashMap<StoreKey, StoredRep>) -> u64 {
    BIN_HEADER_LEN as u64
        + entries
            .iter()
            .map(|(k, sr)| frame_len(k, &sr.outcome) as u64)
            .sum::<u64>()
}

/// Drop least-recently-used records until the index fits `cap` bytes,
/// returning what was removed (so a failed index rewrite can restore
/// them).  Paper-plane repetitions are pinned — they are the online
/// trainer's training data ([`crate::coordinator::Trainer`] tails
/// exactly those keys) and must never vanish between two of its polls.
/// Eviction order is deterministic: ascending `(touch, key)`.  When
/// pinned records alone exceed the cap, everything unpinned goes and
/// the overshoot is kept (with a warning) rather than dropping
/// training data.
fn evict_to_cap(
    entries: &mut HashMap<StoreKey, StoredRep>,
    cap: u64,
) -> Vec<(StoreKey, StoredRep)> {
    let mut total = index_bytes(entries);
    if total <= cap {
        return Vec::new();
    }
    let mut candidates: Vec<(u64, StoreKey)> = entries
        .iter()
        .filter(|(k, _)| !k.is_paper_plane())
        .map(|(k, sr)| (sr.touch, *k))
        .collect();
    candidates.sort();
    let mut evicted = Vec::new();
    for (_, key) in candidates {
        if total <= cap {
            break;
        }
        if let Some(sr) = entries.remove(&key) {
            total -= frame_len(&key, &sr.outcome) as u64;
            evicted.push((key, sr));
        }
    }
    if total > cap {
        eprintln!(
            "store: size cap {cap} B is below the pinned paper-plane \
             records ({total} B); keeping them anyway"
        );
    }
    evicted
}

/// Rewrite the index from `entries` as binary v3 via write-to-temp +
/// atomic rename.  Must only be called while holding the
/// [`CompactGuard`].
fn write_index(
    dir: &Path,
    entries: &HashMap<StoreKey, StoredRep>,
) -> Result<(), String> {
    // Key-sorted records make the index byte-deterministic: compacting an
    // already-compact store rewrites the identical file (idempotence).
    let mut records: Vec<(&StoreKey, &StoredRep)> = entries.iter().collect();
    records.sort_by(|a, b| a.0.cmp(b.0));
    let mut body = Vec::with_capacity(
        BIN_HEADER_LEN + records.len() * 96,
    );
    body.extend_from_slice(&bin_header());
    for (key, sr) in records {
        encode_record_bin_into(key, &sr.outcome, sr.touch, &mut body);
    }
    let tmp = dir.join(format!("{INDEX_FILE}.tmp-{}", std::process::id()));
    fs::write(&tmp, &body).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    fs::rename(&tmp, dir.join(INDEX_FILE))
        .map_err(|e| format!("rename {}: {e}", tmp.display()))
}

/// Holds `compact.lock` for the duration of one scan-and-rewrite pass.
struct CompactGuard {
    path: PathBuf,
}

impl CompactGuard {
    fn acquire(dir: &Path) -> Option<CompactGuard> {
        let path = dir.join(COMPACT_LOCK);
        for attempt in 0..2 {
            match OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = writeln!(f, "{}", std::process::id());
                    return Some(CompactGuard { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    // A crashed compactor must not disable compaction
                    // forever: reclaim locks far older than any real
                    // pass and retry once.
                    if attempt == 0 && compact_lock_is_stale(&path) {
                        eprintln!(
                            "store: reclaiming stale {}",
                            path.display()
                        );
                        let _ = fs::remove_file(&path);
                        continue;
                    }
                    return None;
                }
                Err(_) => return None,
            }
        }
        None
    }
}

fn compact_lock_is_stale(path: &Path) -> bool {
    fs::metadata(path)
        .and_then(|m| m.modified())
        .ok()
        .and_then(|t| t.elapsed().ok())
        .map(|age| age > STALE_COMPACT_LOCK)
        .unwrap_or(false)
}

impl Drop for CompactGuard {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(m: u32, r: u32, rep: u32, seed: u64) -> StoreKey {
        StoreKey {
            cluster: 0xDEAD_BEEF_0BAD_F00D,
            app: AppId::WordCount,
            num_mappers: m,
            num_reducers: r,
            input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
            block_mb: StoreKey::PAPER_BLOCK_MB,
            rep,
            base_seed: seed,
        }
    }

    /// A record line exactly as the v1 (PR 2) store wrote it.
    fn v1_line(k: &StoreKey, time_s: f64) -> String {
        Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("cluster", Json::Str(hex_u64(k.cluster))),
            ("app", Json::Str(k.app.name().to_string())),
            ("m", Json::Num(k.num_mappers as f64)),
            ("r", Json::Num(k.num_reducers as f64)),
            ("rep", Json::Num(k.rep as f64)),
            ("seed", Json::Str(hex_u64(k.base_seed))),
            ("bits", Json::Str(hex_u64(time_s.to_bits()))),
            ("t", Json::Num(time_s)),
        ])
        .to_string()
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_store_unit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn jsonl_record_round_trips_bit_exactly() {
        for (i, t) in [1523.25, 0.1 + 0.2, f64::MIN_POSITIVE, 1e300].iter().enumerate() {
            let mut k = key(20, 5, i as u32, u64::MAX - i as u64);
            k.input_gb_bits = (1.5 + i as f64).to_bits();
            k.block_mb = 32 << i;
            for outcome in
                [RepOutcome::full(*t, t * 4.0 + 1.0), RepOutcome::time_only(*t)]
            {
                let line = encode_record(&k, &outcome);
                let (k2, o2, ver) = decode_record(&line).unwrap();
                assert_eq!(k2, k);
                assert_eq!(ver, JSONL_RECORD_VERSION);
                assert!(o2.same_bits(&outcome));
            }
        }
    }

    #[test]
    fn binary_record_round_trips_bit_exactly() {
        for (i, t) in
            [1523.25, 0.1 + 0.2, f64::MIN_POSITIVE, 1e300, f64::NAN]
                .iter()
                .enumerate()
        {
            let mut k = key(20, 5, i as u32, u64::MAX - i as u64);
            k.input_gb_bits = (1.5 + i as f64).to_bits();
            k.block_mb = 32 << i;
            for outcome in
                [RepOutcome::full(*t, t * 4.0 + 1.0), RepOutcome::time_only(*t)]
            {
                let frame = encode_record_bin(&k, &outcome, 77 + i as u64);
                assert_eq!(frame.len(), frame_len(&k, &outcome));
                let (k2, o2, touch, used) = decode_record_bin(&frame).unwrap();
                assert_eq!(k2, k);
                assert_eq!(touch, 77 + i as u64);
                assert_eq!(used, frame.len());
                assert!(o2.same_bits(&outcome));
            }
        }
    }

    #[test]
    fn binary_decode_rejects_truncation_and_garbage() {
        let frame = encode_record_bin(
            &key(5, 5, 0, 1),
            &RepOutcome::full(2.0, 3.0),
            9,
        );
        for cut in [0, 3, 4, frame.len() - 1] {
            assert!(decode_record_bin(&frame[..cut]).is_err(), "cut {cut}");
        }
        // A garbled length prefix is implausible, not a panic.
        let mut bad = frame.clone();
        bad[0] = 0xFF;
        bad[1] = 0xFF;
        bad[2] = 0xFF;
        bad[3] = 0x7F;
        assert!(decode_record_bin(&bad).is_err());
        // Trailing payload bytes are rejected (payload must be exact).
        let mut padded = frame.clone();
        let len = u32::from_le_bytes(padded[0..4].try_into().unwrap()) + 1;
        padded[0..4].copy_from_slice(&len.to_le_bytes());
        padded.push(0);
        assert!(decode_record_bin(&padded).is_err());
    }

    #[test]
    fn decode_classifies_stale_and_corrupt() {
        let line = encode_record(&key(5, 5, 0, 1), &RepOutcome::full(2.0, 3.0));
        let stale = line.replace("\"v\":2", "\"v\":999");
        assert_eq!(
            decode_record(&stale),
            Err(RecordError::StaleVersion(999))
        );
        for bad in ["", "not json", "{\"v\":2}", "{\"v\":1}", "{\"x\":2}", "[1,2,3]"] {
            match decode_record(bad) {
                Err(RecordError::Corrupt(_)) => {}
                other => panic!("expected corrupt for {bad:?}, got {other:?}"),
            }
        }
    }

    #[test]
    fn v1_lines_migrate_to_paper_default_keys() {
        let k = key(20, 5, 3, 42);
        let (k2, o2, ver) = decode_record(&v1_line(&k, 1523.25)).unwrap();
        assert_eq!(ver, 1);
        // The migrated key lands exactly where the 2-parameter executor
        // path keys its reps: the paper-default input/block plane.
        assert_eq!(k2, k);
        assert_eq!(k2.input_gb(), StoreKey::PAPER_INPUT_GB);
        assert_eq!(k2.block_mb, StoreKey::PAPER_BLOCK_MB);
        assert!(k2.is_paper_plane());
        assert_eq!(o2, RepOutcome::time_only(1523.25));
    }

    #[test]
    fn v1_segment_survives_compaction_and_answers_v3_lookup() {
        let dir = tmp_dir("migrate");
        std::fs::create_dir_all(&dir).unwrap();
        let k = key(20, 5, 0, 7);
        std::fs::write(
            dir.join("seg-cafe0000-0000-legacy.jsonl"),
            format!("{}\n{}\n", v1_line(&k, 100.5), v1_line(&key(20, 5, 1, 7), 101.5)),
        )
        .unwrap();
        {
            let store = ProfileStore::open(&dir).unwrap();
            let st = store.stats();
            assert_eq!(st.migrated_lines, 2);
            assert_eq!(st.merged_segments, 1, "v1 segment folded, not orphaned");
            assert_eq!(st.stale_lines, 0);
            assert_eq!(store.get(&k), Some(RepOutcome::time_only(100.5)));
        }
        // The rewritten index is pure v3 binary and still answers after
        // reopen.
        let recs = read_file_records(&dir.join(INDEX_FILE)).unwrap();
        assert_eq!(recs.len(), 2);
        assert!(recs.iter().all(|(_, _, v)| *v == STORE_FORMAT_VERSION));
        assert!(!dir.join(LEGACY_INDEX_FILE).exists());
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.stats().migrated_lines, 0, "migration is one-time");
        assert_eq!(store.get(&k), Some(RepOutcome::time_only(100.5)));
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_jsonl_index_is_rewritten_as_binary() {
        let dir = tmp_dir("legacy_index");
        std::fs::create_dir_all(&dir).unwrap();
        let k = key(10, 10, 0, 3);
        std::fs::write(
            dir.join(LEGACY_INDEX_FILE),
            format!("{}\n", encode_record(&k, &RepOutcome::full(5.0, 1.0))),
        )
        .unwrap();
        {
            // No segments at all — the legacy index alone triggers the
            // upgrade compaction.
            let store = ProfileStore::open(&dir).unwrap();
            assert!(store.stats().compacted);
            assert_eq!(store.get(&k), Some(RepOutcome::full(5.0, 1.0)));
        }
        assert!(dir.join(INDEX_FILE).exists());
        assert!(!dir.join(LEGACY_INDEX_FILE).exists());
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.get(&k), Some(RepOutcome::full(5.0, 1.0)));
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_binary_file_is_preserved_not_merged() {
        let dir = tmp_dir("stale_bin");
        std::fs::create_dir_all(&dir).unwrap();
        // A segment written by a hypothetical v4 build.
        let mut future = Vec::new();
        future.extend_from_slice(&BIN_MAGIC);
        future.extend_from_slice(&4u32.to_le_bytes());
        future.extend_from_slice(&[1, 2, 3, 4]);
        let seg = dir.join("seg-feed0000-0000-future.bin");
        std::fs::write(&seg, &future).unwrap();
        let store = ProfileStore::open(&dir).unwrap();
        let st = store.stats();
        assert_eq!(st.stale_lines, 1, "future file counted as stale");
        assert_eq!(st.corrupt_lines, 0);
        assert!(seg.exists(), "preserved for the build that understands it");
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_outcome_beats_migrated_duplicate_in_any_load_order() {
        let k = key(10, 10, 0, 1);
        let full = RepOutcome::full(55.0, 44.0);
        for lines in [
            // v1-migrated first, upgrade second ...
            format!("{}\n{}\n", v1_line(&k, 55.0), encode_record(&k, &full)),
            // ... and the reverse: the full outcome must win either way.
            format!("{}\n{}\n", encode_record(&k, &full), v1_line(&k, 55.0)),
        ] {
            let mut entries = HashMap::new();
            let mut stats = StoreStats::default();
            load_lines(Path::new("test"), &lines, &mut entries, &mut stats);
            assert_eq!(stats.migrated_lines, 2, "v1 and v2 lines both migrate");
            assert_eq!(entries.get(&k).map(|sr| sr.outcome), Some(full));
        }
    }

    #[test]
    fn put_get_flush_reopen() {
        let dir = tmp_dir("basic");
        {
            let store = ProfileStore::open(&dir).unwrap();
            assert!(store.is_empty());
            store.put(key(20, 5, 0, 42), RepOutcome::full(100.5, 1.25));
            store.put(key(20, 5, 1, 42), RepOutcome::full(101.5, 2.25));
            assert_eq!(store.pending(), 2);
            store.flush().unwrap();
            assert_eq!(store.pending(), 0);
            assert_eq!(
                store.get(&key(20, 5, 0, 42)),
                Some(RepOutcome::full(100.5, 1.25))
            );
        }
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(
            store.get(&key(20, 5, 1, 42)),
            Some(RepOutcome::full(101.5, 2.25))
        );
        assert!(store.get(&key(20, 5, 2, 42)).is_none());
        drop(store);
        assert!(ProfileStore::clear(&dir).unwrap() >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rewriting_known_value_stays_clean() {
        let dir = tmp_dir("rewrite");
        let store = ProfileStore::open(&dir).unwrap();
        store.put(key(5, 5, 0, 7), RepOutcome::full(3.5, 0.5));
        store.flush().unwrap();
        store.put(key(5, 5, 0, 7), RepOutcome::full(3.5, 0.5));
        assert_eq!(store.pending(), 0, "identical value not re-queued");
        // A CPU-less duplicate (migration debris) is not queued either,
        // and does not displace the full outcome.
        store.put(key(5, 5, 0, 7), RepOutcome::time_only(3.5));
        assert_eq!(store.pending(), 0, "downgrade never queued");
        assert_eq!(store.get(&key(5, 5, 0, 7)), Some(RepOutcome::full(3.5, 0.5)));
        // But a full outcome upgrades a CPU-less record in place.
        store.put(key(6, 6, 0, 7), RepOutcome::time_only(9.0));
        store.put(key(6, 6, 0, 7), RepOutcome::full(9.0, 1.0));
        assert_eq!(store.pending(), 2, "upgrade re-queued");
        assert_eq!(store.get(&key(6, 6, 0, 7)), Some(RepOutcome::full(9.0, 1.0)));
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drop_flushes_and_releases_lock() {
        let dir = tmp_dir("droplock");
        {
            let store = ProfileStore::open(&dir).unwrap();
            store.put(key(10, 10, 0, 9), RepOutcome::full(55.0, 5.0));
            store.flush().unwrap();
            // Live session: exactly one lock file present.
            let locks = std::fs::read_dir(&dir)
                .unwrap()
                .filter(|e| {
                    e.as_ref()
                        .unwrap()
                        .file_name()
                        .to_string_lossy()
                        .ends_with(".lock")
                })
                .count();
            assert_eq!(locks, 1);
        }
        let locks = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref()
                    .unwrap()
                    .file_name()
                    .to_string_lossy()
                    .ends_with(".lock")
            })
            .count();
        assert_eq!(locks, 0, "locks released on drop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_of_missing_dir_is_empty() {
        let dir = tmp_dir("missing");
        assert_eq!(ProfileStore::clear(&dir).unwrap(), 0);
    }

    #[test]
    fn generation_counts_disk_and_live_insertions() {
        let dir = tmp_dir("generation");
        {
            let store = ProfileStore::open(&dir).unwrap();
            assert_eq!(store.generation(), 0);
            store.put(key(20, 5, 0, 1), RepOutcome::full(100.0, 1.0));
            store.put(key(20, 5, 1, 1), RepOutcome::full(101.0, 2.0));
            assert_eq!(store.generation(), 2);
            // Re-putting a known value does not advance the generation.
            store.put(key(20, 5, 0, 1), RepOutcome::full(100.0, 1.0));
            assert_eq!(store.generation(), 2);
            store.flush().unwrap();
        }
        // A fresh open seeds the journal with everything on disk.
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.generation(), 2);
        let (all, generation) = store.read_since(0);
        assert_eq!(generation, 2);
        assert_eq!(all.len(), 2);
        // Sorted by key: rep 0 before rep 1.
        assert_eq!(all[0].0.rep, 0);
        assert_eq!(all[1].0.rep, 1);
        // Tail from the snapshot: nothing new yet.
        let (fresh, g2) = store.read_since(generation);
        assert!(fresh.is_empty());
        assert_eq!(g2, generation);
        store.put(key(30, 5, 0, 1), RepOutcome::full(200.0, 3.0));
        let (fresh, g3) = store.read_since(generation);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].0.num_mappers, 30);
        assert_eq!(g3, 3);
        // A generation past the end is clamped, not a panic.
        assert!(store.read_since(u64::MAX).0.is_empty());
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresh_picks_up_other_sessions_records() {
        let dir = tmp_dir("refresh");
        let reader = ProfileStore::open(&dir).unwrap();
        let before = reader.generation();
        // A concurrent writer session appends and flushes two records.
        {
            let writer = ProfileStore::open(&dir).unwrap();
            writer.put(key(10, 10, 0, 5), RepOutcome::full(50.0, 5.0));
            writer.put(key(10, 10, 1, 5), RepOutcome::full(51.0, 6.0));
            writer.flush().unwrap();
        }
        // Invisible until refresh ...
        assert!(reader.get(&key(10, 10, 0, 5)).is_none());
        assert_eq!(reader.refresh().unwrap(), 2);
        assert_eq!(
            reader.get(&key(10, 10, 0, 5)),
            Some(RepOutcome::full(50.0, 5.0))
        );
        let (fresh, _) = reader.read_since(before);
        assert_eq!(fresh.len(), 2);
        // ... and refreshing again finds nothing new.
        assert_eq!(reader.refresh().unwrap(), 0);
        drop(reader);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresh_never_downgrades_a_full_outcome() {
        let dir = tmp_dir("refresh_downgrade");
        let reader = ProfileStore::open(&dir).unwrap();
        let k = key(15, 15, 0, 9);
        reader.put(k, RepOutcome::full(70.0, 7.0));
        // Another session leaves a CPU-less duplicate on disk (v1 debris).
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("seg-beef0000-0000-dup.jsonl"),
            format!("{}\n", encode_record(&k, &RepOutcome::time_only(70.0))),
        )
        .unwrap();
        assert_eq!(reader.refresh().unwrap(), 0, "downgrade not folded");
        assert_eq!(reader.get(&k), Some(RepOutcome::full(70.0, 7.0)));
        drop(reader);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A key off the paper plane, with a distinguishable index `i` and a
    /// put order that fixes its touch generation.
    fn ext4_key(i: u32) -> StoreKey {
        StoreKey {
            cluster: 0xDEAD_BEEF_0BAD_F00D,
            app: AppId::WordCount,
            num_mappers: 5 + i,
            num_reducers: 7,
            input_gb_bits: 2.0f64.to_bits(),
            block_mb: 128,
            rep: 0,
            base_seed: 2,
        }
    }

    #[test]
    fn eviction_respects_cap_and_pins_paper_plane() {
        let dir = tmp_dir("evict");
        {
            let store = ProfileStore::open(&dir).unwrap();
            // Paper-plane reps first: the *lowest* touch generations, so
            // only pinning (not recency) can save them.
            for rep in 0..4 {
                store.put(key(20, 5, rep, 1), RepOutcome::full(100.0 + rep as f64, 1.0));
            }
            // Then 50 extended-sweep records, touches ascending with i.
            for i in 0..50 {
                store.put(ext4_key(i), RepOutcome::full(10.0 + i as f64, 0.5));
            }
            store.flush().unwrap();
        }
        let store = ProfileStore::open_capped(&dir, Some(2048)).unwrap();
        let st = store.stats();
        assert!(st.compacted);
        assert!(st.evicted > 0, "cap forced eviction: {st}");
        assert!(
            std::fs::metadata(dir.join(INDEX_FILE)).unwrap().len() <= 2048,
            "index fits the cap"
        );
        for rep in 0..4 {
            assert!(
                store.get(&key(20, 5, rep, 1)).is_some(),
                "paper-plane rep {rep} pinned"
            );
        }
        // LRU order: the coldest extended record went first, the hottest
        // survived.
        assert!(store.get(&ext4_key(0)).is_none(), "coldest evicted");
        assert!(store.get(&ext4_key(49)).is_some(), "hottest kept");
        drop(store);
        // Eviction is durable: an uncapped reopen does not resurrect.
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.stats().evicted, 0);
        assert!(store.get(&ext4_key(0)).is_none());
        assert!(store.get(&key(20, 5, 0, 1)).is_some());
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn capped_open_without_pressure_evicts_nothing() {
        let dir = tmp_dir("evict_none");
        {
            let store = ProfileStore::open(&dir).unwrap();
            for i in 0..10 {
                store.put(ext4_key(i), RepOutcome::full(1.0 + i as f64, 0.1));
            }
            store.flush().unwrap();
        }
        let store =
            ProfileStore::open_capped(&dir, Some(1024 * 1024)).unwrap();
        assert_eq!(store.stats().evicted, 0);
        assert_eq!(store.len(), 10);
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lookup_hits_refresh_recency_across_sessions() {
        let dir = tmp_dir("touch");
        {
            let store = ProfileStore::open(&dir).unwrap();
            for i in 0..20 {
                store.put(ext4_key(i), RepOutcome::full(1.0 + i as f64, 0.1));
            }
            store.flush().unwrap();
        }
        {
            // A second *capped* session uses the coldest record; the
            // hit's touch bump is persisted on drop.  (An uncapped
            // session bumps recency in memory only — warm runs without a
            // cap must stay write-free.)
            let store =
                ProfileStore::open_capped(&dir, Some(1024 * 1024)).unwrap();
            assert!(store.get(&ext4_key(0)).is_some());
        }
        // Cap sized to keep only a handful: the freshly-used record 0
        // must now outlive colder neighbours.
        let store = ProfileStore::open_capped(&dir, Some(400)).unwrap();
        assert!(store.stats().evicted > 0);
        assert!(store.get(&ext4_key(0)).is_some(), "recent hit survives");
        assert!(store.get(&ext4_key(1)).is_none(), "cold neighbour evicted");
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
