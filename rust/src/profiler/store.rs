//! Persistent, versioned, on-disk profile store.
//!
//! Profiling is the expensive phase of the paper's pipeline — every
//! setting is simulated repeatedly before regression modeling can begin —
//! and PR 1's in-memory executor cache only helps within one process.
//! This store spills that cache to disk so *any* CLI invocation
//! (`profile`, `fig3`, `fig4`, `table1`, `e2e`, `serve`, scheduler
//! what-ifs) warm-starts from every prior session on the machine.
//!
//! # On-disk layout
//!
//! A store is a directory:
//!
//! ```text
//! store/
//!   index.jsonl            compacted records (atomically replaced)
//!   seg-<pid>-<n>-<t>.jsonl  append-only segment, one per writing session
//!   seg-....jsonl.lock     liveness lock while that segment is open
//!   compact.lock           held briefly while rewriting the index
//! ```
//!
//! Each line is one record, serialized with the repo's hand-rolled JSON
//! ([`crate::util::json`]).  `u64` values (cluster fingerprint, session
//! seed, input-size bits) and the `f64` outcome figures (execution time
//! and CPU seconds) travel as fixed-width hex strings
//! ([`crate::util::bytes::hex_u64`]) so every bit round-trips — stored
//! values are the same bit-identical rep results the executor produces,
//! which is what makes warm runs byte-identical to cold ones.
//!
//! # Concurrency and crash safety
//!
//! * Every writing session appends to its **own** uniquely-named segment
//!   file, so two processes sharing a store directory never interleave
//!   writes.
//! * A live segment is marked by a `.lock` file (created before the
//!   segment, removed on drop); compaction merges a locked segment's
//!   flushed lines but never deletes the file under a live writer.
//!   Locks carry the writer's pid — a lock whose process is gone
//!   (crashed session) is reclaimed together with its segment.
//! * On open, segments are folded into `index.jsonl` via
//!   write-to-temp + atomic rename, guarded by `compact.lock` taken
//!   *before* the directory is read (`create_new`, so only one process
//!   compacts at a time; losers just skip the pass, and a stale lock
//!   left by a crashed compactor is reclaimed after ten minutes).
//! * Corruption is tolerated, never fatal: an unreadable file or a
//!   truncated/garbled line is counted, logged to stderr, and skipped.
//!   Lines whose `"v"` field is *newer* than [`STORE_FORMAT_VERSION`]
//!   are skipped too, and their segment is preserved for whichever build
//!   understands it; v1 lines are migrated on read (see
//!   [`STORE_FORMAT_VERSION`]) and rewritten as v2 by compaction.

use std::collections::HashMap;
use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use crate::apps::AppId;
use crate::mr::RepOutcome;
use crate::util::bytes::{hex_u64, parse_hex_u64};
use crate::util::json::{parse, Json};

/// Store format version; bump when the record schema changes.
///
/// * **v1** (PR 2): 2-parameter keys `(cluster, app, m, r, rep, seed)`
///   holding a bare execution time.
/// * **v2**: keys additionally carry `input_gb`/`block_mb` (the extended
///   4-parameter sweep axes) and records hold a [`RepOutcome`] — total
///   time plus total CPU seconds.  v1 lines are **migrated on read**:
///   they decode into v2 keys at the paper-default input/block values
///   with the CPU figure absent, so existing stores keep answering.
///
/// Readers skip (and preserve) records of any *newer* version.
pub const STORE_FORMAT_VERSION: u32 = 2;

const INDEX_FILE: &str = "index.jsonl";
const COMPACT_LOCK: &str = "compact.lock";

/// A `compact.lock` older than this is assumed to be the debris of a
/// crashed process (a compaction pass takes well under a second) and is
/// reclaimed, so one crash can never disable compaction forever.
const STALE_COMPACT_LOCK: Duration = Duration::from_secs(600);

/// Distinguishes session segments from everything else in the directory.
const SEGMENT_PREFIX: &str = "seg-";
const SEGMENT_SUFFIX: &str = ".jsonl";

/// Makes segment names unique when one process opens several stores (or
/// several executors share a directory) within one clock tick.
static SEG_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Identity of one simulated repetition — the executor's cache key made
/// persistent.  The cluster fingerprint keeps times from one hardware
/// model from ever answering for another; `base_seed` keys the profiling
/// session so distinct sessions never alias.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StoreKey {
    /// Fingerprint of every simulation-relevant cluster field.
    pub cluster: u64,
    /// Application profiled.
    pub app: AppId,
    /// Number of map tasks (the paper's first parameter).
    pub num_mappers: u32,
    /// Number of reduce tasks (the paper's second parameter).
    pub num_reducers: u32,
    /// Input size in GB — the extended sweep's third parameter — as raw
    /// `f64` bits (`f64` has no `Eq`/`Hash`; bits keep the key exact).
    /// The paper's own setup is [`StoreKey::PAPER_INPUT_GB`].
    pub input_gb_bits: u64,
    /// HDFS block size in MB — the extended sweep's fourth parameter.
    /// The paper's own setup is [`StoreKey::PAPER_BLOCK_MB`].
    pub block_mb: u32,
    /// Repetition index within the profiling session.
    pub rep: u32,
    /// Profiling-session seed.
    pub base_seed: u64,
}

impl StoreKey {
    /// Input size of the paper's testbed (`JobConfig::paper_default`) —
    /// where 2-parameter keys, and migrated v1 records, live in the 4-D
    /// parameter space.
    pub const PAPER_INPUT_GB: f64 = 8.0;
    /// HDFS block size of the paper's testbed.
    pub const PAPER_BLOCK_MB: u32 = 64;

    /// Input size in GB.
    pub fn input_gb(&self) -> f64 {
        f64::from_bits(self.input_gb_bits)
    }
}

/// Why a record line failed to decode.
#[derive(Clone, Debug, PartialEq)]
pub enum RecordError {
    /// The line is a record of a store-format version this build cannot
    /// read (newer than [`STORE_FORMAT_VERSION`], or 0/garbage).
    StaleVersion(u64),
    /// The line is not a valid record at all (truncated write, garbage).
    Corrupt(String),
}

/// Serialize one `(key, per-rep outcome)` record as a v2 JSON line.
pub fn encode_record(key: &StoreKey, outcome: &RepOutcome) -> String {
    // "t"/"cpu" are redundant human-readable copies; the hex "bits"
    // fields are authoritative.  "cbits"/"cpu" are omitted when the CPU
    // figure is unknown (v1-migrated data).
    let mut pairs = vec![
        ("v", Json::Num(STORE_FORMAT_VERSION as f64)),
        ("cluster", Json::Str(hex_u64(key.cluster))),
        ("app", Json::Str(key.app.name().to_string())),
        ("m", Json::Num(key.num_mappers as f64)),
        ("r", Json::Num(key.num_reducers as f64)),
        ("igb", Json::Str(hex_u64(key.input_gb_bits))),
        ("blk", Json::Num(key.block_mb as f64)),
        ("rep", Json::Num(key.rep as f64)),
        ("seed", Json::Str(hex_u64(key.base_seed))),
        ("bits", Json::Str(hex_u64(outcome.time_s.to_bits()))),
        ("t", Json::Num(outcome.time_s)),
    ];
    if let Some(cpu) = outcome.cpu_s {
        pairs.push(("cbits", Json::Str(hex_u64(cpu.to_bits()))));
        pairs.push(("cpu", Json::Num(cpu)));
    }
    Json::obj(pairs).to_string()
}

/// Decode a record line written by [`encode_record`] (v2) or by the v1
/// store, returning the key, the outcome, and the version the line was
/// written under.
///
/// v1 lines are migrated on the fly: their key lands at the paper-default
/// input/block values (the only point v1 could describe) and the CPU
/// figure is absent — they are never orphaned, and compaction rewrites
/// them as v2.
pub fn decode_record(
    line: &str,
) -> Result<(StoreKey, RepOutcome, u32), RecordError> {
    let v = parse(line).map_err(RecordError::Corrupt)?;
    let ver = v.req_u64("v").map_err(RecordError::Corrupt)?;
    let decode = |legacy_v1: bool| -> Result<(StoreKey, RepOutcome), String> {
        let (input_gb_bits, block_mb) = if legacy_v1 {
            (StoreKey::PAPER_INPUT_GB.to_bits(), StoreKey::PAPER_BLOCK_MB)
        } else {
            (parse_hex_u64(v.req_str("igb")?)?, v.req_u32("blk")?)
        };
        let key = StoreKey {
            cluster: parse_hex_u64(v.req_str("cluster")?)?,
            app: AppId::parse(v.req_str("app")?)?,
            num_mappers: v.req_u32("m")?,
            num_reducers: v.req_u32("r")?,
            input_gb_bits,
            block_mb,
            rep: v.req_u32("rep")?,
            base_seed: parse_hex_u64(v.req_str("seed")?)?,
        };
        let time_s = f64::from_bits(parse_hex_u64(v.req_str("bits")?)?);
        let cpu_s = match v.get("cbits") {
            None => None,
            Some(j) => Some(f64::from_bits(parse_hex_u64(
                j.as_str().ok_or("cbits: expected hex string")?,
            )?)),
        };
        Ok((key, RepOutcome { time_s, cpu_s }))
    };
    match ver {
        2 => decode(false)
            .map(|(k, o)| (k, o, 2))
            .map_err(RecordError::Corrupt),
        1 => decode(true)
            .map(|(k, o)| (k, o, 1))
            .map_err(RecordError::Corrupt),
        other => Err(RecordError::StaleVersion(other)),
    }
}

/// What `open` saw on disk, plus the live pending-write count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Distinct records currently loaded.
    pub entries: usize,
    /// Segment files present when the store was opened.
    pub segments_seen: usize,
    /// Segments folded into the index (and deleted) by the open pass.
    pub merged_segments: usize,
    /// Files that could not be read at all (skipped, logged).
    pub corrupt_segments: usize,
    /// Undecodable lines inside otherwise readable files.
    pub corrupt_lines: usize,
    /// Lines of a *newer* store-format version (skipped, preserved).
    pub stale_lines: usize,
    /// v1 lines migrated on read into v2 keys (rewritten as v2 by the
    /// next compaction).
    pub migrated_lines: usize,
    /// Whether the open pass rewrote the index.
    pub compacted: bool,
}

impl std::fmt::Display for StoreStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "entries={} segments_seen={} merged={} corrupt_segments={} \
             corrupt_lines={} stale_lines={} migrated={} compacted={}",
            self.entries,
            self.segments_seen,
            self.merged_segments,
            self.corrupt_segments,
            self.corrupt_lines,
            self.stale_lines,
            self.migrated_lines,
            self.compacted
        )
    }
}

struct SegmentWriter {
    file: fs::File,
    lock: PathBuf,
}

impl SegmentWriter {
    /// Create a fresh uniquely-named segment, taking its liveness lock
    /// *first* so a concurrent compaction never deletes it underneath us.
    fn create(dir: &Path) -> Result<SegmentWriter, String> {
        let nonce = SEG_COUNTER.fetch_add(1, Ordering::Relaxed);
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let name = format!(
            "{SEGMENT_PREFIX}{:08x}-{:04x}-{}{SEGMENT_SUFFIX}",
            std::process::id(),
            nonce,
            hex_u64(nanos)
        );
        let path = dir.join(&name);
        let lock = lock_path(&path);
        let mut lf = OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&lock)
            .map_err(|e| format!("store: create lock {}: {e}", lock.display()))?;
        let _ = writeln!(lf, "{}", std::process::id());
        let file = OpenOptions::new()
            .append(true)
            .create_new(true)
            .open(&path)
            .map_err(|e| format!("store: create segment {}: {e}", path.display()))?;
        Ok(SegmentWriter { file, lock })
    }
}

impl Drop for SegmentWriter {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.lock);
    }
}

struct Inner {
    /// Key → stored per-rep outcome (held as the very `f64`s that were
    /// decoded/produced, so every bit round-trips by construction).
    entries: HashMap<StoreKey, RepOutcome>,
    /// Key of every record this store instance has accepted, in
    /// acceptance order: the on-disk records found at open (sorted, so
    /// the order is deterministic), then every `put`/`refresh`
    /// insertion.  `journal.len()` is the store's **generation**;
    /// consumers tail the store by remembering the generation they last
    /// read ([`ProfileStore::read_since`]).  Keys only — the outcome
    /// always lives in `entries` (which never shrinks), so the journal
    /// does not double the store's resident memory.  An upgraded record
    /// (CPU figure added) appears twice; both occurrences resolve to
    /// the live (upgraded) outcome.
    journal: Vec<StoreKey>,
    /// Encoded lines not yet appended to this session's segment.
    dirty: Vec<String>,
    /// Lazily created on first flush, so read-only sessions leave no file.
    writer: Option<SegmentWriter>,
}

/// The persistent profile store: an in-memory view of every record on
/// disk, plus an append-only writer for this session's new results.
///
/// The [`super::CampaignExecutor`] reads through it on cache misses and
/// writes freshly simulated reps back; `flush` runs at campaign
/// boundaries and on drop.  All methods take `&self` and are safe to call
/// from the executor's worker threads.
pub struct ProfileStore {
    dir: PathBuf,
    inner: Mutex<Inner>,
    stats: StoreStats,
    /// Per-file refresh bookkeeping: store file name → length as of the
    /// last successful ingest of that file.  [`ProfileStore::refresh`]
    /// re-parses only files whose length changed (segments are
    /// append-only; the index is replaced wholesale by compaction), so
    /// an idle poll is a directory stat and a steady-state poll costs
    /// the changed files, not the whole store.
    refresh_state: Mutex<HashMap<String, u64>>,
}

impl ProfileStore {
    /// Open (creating if needed) the store at `dir`, folding any
    /// completed segments into the index — the compaction pass.
    pub fn open(dir: &Path) -> Result<ProfileStore, String> {
        ProfileStore::open_with(dir, true)
    }

    /// Open without compacting — inspection (`store stats`) and tests.
    pub fn peek(dir: &Path) -> Result<ProfileStore, String> {
        ProfileStore::open_with(dir, false)
    }

    fn open_with(dir: &Path, compact: bool) -> Result<ProfileStore, String> {
        fs::create_dir_all(dir)
            .map_err(|e| format!("store: create dir {}: {e}", dir.display()))?;

        // The compaction lock must be taken *before* reading: compaction
        // is a read-modify-write of the whole directory, and rewriting
        // the index from a pre-lock snapshot could overwrite a newer
        // index whose source segments are already deleted — losing data.
        let guard = if compact { CompactGuard::acquire(dir) } else { None };
        if compact && guard.is_none() {
            eprintln!("store: compaction lock busy; skipping compaction pass");
        }

        let scan = scan_dir(dir)?;
        let mut stats = scan.stats;
        if guard.is_some() && !scan.mergeable.is_empty() {
            if scan.index_unreadable {
                // Rewriting the index now would replace the (unreadable
                // but possibly recoverable) old index with segment data
                // only.  Leave everything in place for manual recovery.
                eprintln!(
                    "store: index unreadable; compaction disabled to avoid data loss"
                );
            } else {
                match write_index(dir, &scan.entries) {
                    Ok(()) => {
                        for p in &scan.mergeable {
                            // Best-effort; also reclaim a dead writer's
                            // leftover lock so it stops shadowing opens.
                            let _ = fs::remove_file(p);
                            let _ = fs::remove_file(lock_path(p));
                        }
                        stats.compacted = true;
                        stats.merged_segments = scan.mergeable.len();
                    }
                    Err(e) => eprintln!("store: compaction skipped: {e}"),
                }
            }
        }
        drop(guard);

        stats.entries = scan.entries.len();
        // Seed the journal with everything already on disk, sorted by key
        // so the initial generation's contents are deterministic.
        let mut journal: Vec<StoreKey> = scan.entries.keys().copied().collect();
        journal.sort();
        Ok(ProfileStore {
            dir: dir.to_path_buf(),
            inner: Mutex::new(Inner {
                entries: scan.entries,
                journal,
                dirty: Vec::new(),
                writer: None,
            }),
            stats,
            refresh_state: Mutex::new(HashMap::new()),
        })
    }

    /// Directory this store lives in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Stats snapshot from the open pass, with `entries` refreshed to the
    /// live count.
    pub fn stats(&self) -> StoreStats {
        let mut s = self.stats;
        s.entries = self.len();
        s
    }

    /// Stored outcome for `key`, if any prior session simulated it.
    pub fn get(&self, key: &StoreKey) -> Option<RepOutcome> {
        let inner = self.inner.lock().expect("store mutex poisoned");
        inner.entries.get(key).copied()
    }

    /// Record a freshly simulated outcome.  Buffered in memory until
    /// [`ProfileStore::flush`]; a value already on disk is not rewritten,
    /// and a CPU-less value (v1-migrated) never displaces a full one —
    /// though a full outcome *does* upgrade a CPU-less record in place.
    pub fn put(&self, key: StoreKey, outcome: RepOutcome) {
        let mut inner = self.inner.lock().expect("store mutex poisoned");
        match inner.entries.get(&key) {
            Some(old) if old.same_bits(&outcome) => {}
            Some(old) if old.cpu_s.is_some() && outcome.cpu_s.is_none() => {}
            _ => {
                inner.entries.insert(key, outcome);
                inner.journal.push(key);
                inner.dirty.push(encode_record(&key, &outcome));
            }
        }
    }

    /// Monotonic change counter: how many records this store instance has
    /// accepted so far (disk records found at open plus every later
    /// insertion).  A consumer that remembers the generation it last saw
    /// reads exactly the new records via [`ProfileStore::read_since`] —
    /// the change-detection contract the online trainer tails.
    pub fn generation(&self) -> u64 {
        self.inner.lock().expect("store mutex poisoned").journal.len() as u64
    }

    /// Every record accepted after `generation`, plus the generation that
    /// snapshot corresponds to (pass it back next time).  `read_since(0)`
    /// returns the whole store in deterministic order.  The stream is an
    /// upsert log: a key may repeat when its record was upgraded in place
    /// (CPU figure added) — every occurrence carries the live record, so
    /// later entries are consistent with earlier ones.
    pub fn read_since(
        &self,
        generation: u64,
    ) -> (Vec<(StoreKey, RepOutcome)>, u64) {
        let inner = self.inner.lock().expect("store mutex poisoned");
        let from = (generation as usize).min(inner.journal.len());
        let records = inner.journal[from..]
            .iter()
            .map(|k| {
                let outcome = inner
                    .entries
                    .get(k)
                    .copied()
                    .expect("journaled key always resident");
                (*k, outcome)
            })
            .collect();
        (records, inner.journal.len() as u64)
    }

    /// Re-scan the store directory and fold in records written by *other*
    /// sessions since this store was opened (their flushed segment lines
    /// and any index rewritten by their compactions).  Returns how many
    /// records were new.  Records this instance already holds are left
    /// untouched — in particular a full outcome is never displaced by a
    /// CPU-less duplicate, and by the determinism invariant equal keys
    /// carry equal values, so keeping the resident record is always
    /// sound.  This is the polling half of the trainer's
    /// profile-store-to-model loop.
    ///
    /// Polls are incremental: store files are fingerprinted by
    /// `(name, length)`, and only *changed* files are re-parsed — an
    /// idle poll is a directory stat, a steady-state poll re-reads just
    /// the growing segment(s), and the (large) index is re-read only
    /// when a compaction replaced it.  Lengths are recorded only after
    /// a file was successfully ingested, so a transient read failure
    /// can never suppress future re-scans; a torn tail line (racing a
    /// writer's flush) is skipped now and re-parsed when the file next
    /// grows, because any completed write changes the length observed
    /// *before* this read started.
    pub fn refresh(&self) -> Result<u64, String> {
        let fingerprint = dir_fingerprint(&self.dir)?;
        let changed: Vec<(String, u64)> = {
            let state =
                self.refresh_state.lock().expect("store refresh-state poisoned");
            fingerprint
                .iter()
                .filter(|(name, len)| state.get(name) != Some(len))
                .cloned()
                .collect()
        };
        if changed.is_empty() {
            return Ok(0);
        }
        // Re-parse only the changed files, tolerating (and logging)
        // corruption exactly like the open pass.
        let mut parsed: HashMap<StoreKey, RepOutcome> = HashMap::new();
        let mut stats = StoreStats::default();
        let mut ingested: Vec<(String, u64)> = Vec::new();
        for (name, len) in changed {
            let path = self.dir.join(&name);
            match fs::read_to_string(&path) {
                Ok(text) => {
                    load_lines(&path, &text, &mut parsed, &mut stats);
                    ingested.push((name, len));
                }
                // Deleted mid-refresh (racing compaction): its records
                // are in the rewritten index, whose length changed too.
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => eprintln!(
                    "store: refresh skipping unreadable {}: {e}",
                    path.display()
                ),
            }
        }
        let mut inner = self.inner.lock().expect("store mutex poisoned");
        let mut fresh: Vec<(StoreKey, RepOutcome)> = parsed
            .into_iter()
            .filter(|(k, o)| match inner.entries.get(k) {
                None => true,
                Some(old) => old.cpu_s.is_none() && o.cpu_s.is_some(),
            })
            .collect();
        // Sort so concurrent writers' records land in the journal in a
        // deterministic order whatever the directory scan produced.
        fresh.sort_by(|a, b| a.0.cmp(&b.0));
        let new_records = fresh.len() as u64;
        for (key, outcome) in fresh {
            inner.entries.insert(key, outcome);
            inner.journal.push(key);
        }
        drop(inner);
        let mut state =
            self.refresh_state.lock().expect("store refresh-state poisoned");
        // Forget files compaction removed, so the map stays bounded by
        // the live file set ...
        state.retain(|name, _| fingerprint.iter().any(|(n, _)| n == name));
        // ... and record the pre-read lengths of what was ingested (a
        // write landing mid-read makes the next poll re-read that file —
        // the safe direction).
        for (name, len) in ingested {
            state.insert(name, len);
        }
        Ok(new_records)
    }

    /// Distinct records currently held (disk + this session's new ones).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("store mutex poisoned").entries.len()
    }

    /// Whether the store holds no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Records buffered but not yet appended to this session's segment.
    pub fn pending(&self) -> usize {
        self.inner.lock().expect("store mutex poisoned").dirty.len()
    }

    /// Append buffered records to this session's segment (created, with
    /// its liveness lock, on first flush).  Called by the executor at
    /// campaign boundaries and from `Drop`.
    pub fn flush(&self) -> Result<(), String> {
        let mut guard = self.inner.lock().expect("store mutex poisoned");
        let inner = &mut *guard;
        if inner.dirty.is_empty() {
            return Ok(());
        }
        if inner.writer.is_none() {
            inner.writer = Some(SegmentWriter::create(&self.dir)?);
        }
        let writer = inner.writer.as_mut().expect("writer just created");
        let mut buf = inner.dirty.join("\n");
        buf.push('\n');
        writer
            .file
            .write_all(buf.as_bytes())
            .map_err(|e| format!("store: append failed: {e}"))?;
        writer
            .file
            .flush()
            .map_err(|e| format!("store: flush failed: {e}"))?;
        inner.dirty.clear();
        Ok(())
    }

    /// Delete every store file under `dir` (index, segments, locks,
    /// leftover temp files).  Returns how many files were removed; a
    /// missing directory is an empty store, not an error.
    pub fn clear(dir: &Path) -> Result<usize, String> {
        let rd = match fs::read_dir(dir) {
            Ok(rd) => rd,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(format!("store: read {}: {e}", dir.display())),
        };
        let mut removed = 0;
        for entry in rd {
            let entry = entry.map_err(|e| format!("store: read dir entry: {e}"))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let ours = name == INDEX_FILE
                || name == COMPACT_LOCK
                || name.starts_with(&format!("{INDEX_FILE}.tmp-"))
                || (name.starts_with(SEGMENT_PREFIX)
                    && (name.ends_with(SEGMENT_SUFFIX)
                        || name.ends_with(&format!("{SEGMENT_SUFFIX}.lock"))));
            if ours {
                fs::remove_file(entry.path())
                    .map_err(|e| format!("store: remove {name}: {e}"))?;
                removed += 1;
            }
        }
        Ok(removed)
    }
}

impl Drop for ProfileStore {
    fn drop(&mut self) {
        if let Err(e) = self.flush() {
            eprintln!("store: flush on drop failed: {e}");
        }
        // Dropping `inner` drops the SegmentWriter, releasing its lock.
    }
}

/// Everything one pass over the store directory learns.
struct Scan {
    entries: HashMap<StoreKey, RepOutcome>,
    /// Segments safe to fold into the index and delete: readable, not
    /// held by a live writer, and free of newer-version records (v1
    /// segments *are* mergeable — migration rewrites them as v2).
    mergeable: Vec<PathBuf>,
    stats: StoreStats,
    /// The index existed but could not be read — compaction must not
    /// rewrite it from segment data alone.
    index_unreadable: bool,
}

/// Read the index and every segment under `dir` into memory, tolerating
/// (and tallying) corruption.  Load order is deterministic (sorted
/// names), and by determinism of the simulator any duplicate keys carry
/// equal values, so later-wins is harmless — with one exception handled
/// in [`load_lines`]: a CPU-less (v1-migrated) duplicate never displaces
/// a full outcome, whatever the load order.
fn scan_dir(dir: &Path) -> Result<Scan, String> {
    let mut scan = Scan {
        entries: HashMap::new(),
        mergeable: Vec::new(),
        stats: StoreStats::default(),
        index_unreadable: false,
    };
    let index_path = dir.join(INDEX_FILE);
    match fs::read_to_string(&index_path) {
        Ok(text) => {
            load_lines(&index_path, &text, &mut scan.entries, &mut scan.stats)
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
        Err(e) => {
            scan.stats.corrupt_segments += 1;
            scan.index_unreadable = true;
            eprintln!(
                "store: skipping unreadable index {}: {e}",
                index_path.display()
            );
        }
    }

    for path in segment_paths(dir)? {
        scan.stats.segments_seen += 1;
        let locked = segment_is_locked(&path);
        match fs::read_to_string(&path) {
            Ok(text) => {
                let stale_before = scan.stats.stale_lines;
                load_lines(&path, &text, &mut scan.entries, &mut scan.stats);
                // A locked segment is still being written; one with
                // other-version lines belongs to another build.  Both
                // are merged-from but never deleted.
                if !locked && scan.stats.stale_lines == stale_before {
                    scan.mergeable.push(path);
                }
            }
            // Raced with another process's compaction: the segment's
            // records are in the index that pass wrote.  Not corruption.
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                scan.stats.corrupt_segments += 1;
                eprintln!(
                    "store: skipping unreadable segment {}: {e}",
                    path.display()
                );
            }
        }
    }
    Ok(scan)
}

/// Liveness-lock path for a segment file (`<segment>.lock`).
fn lock_path(segment: &Path) -> PathBuf {
    let name = segment
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    segment.with_file_name(format!("{name}.lock"))
}

/// Whether `segment` is held by a **live** writer.  Lock files carry the
/// writer's pid; a lock whose process is gone (crashed writer) no longer
/// protects the segment, so compaction can reclaim it.  An empty or
/// garbled lock is treated as live — it may be mid-creation.
fn segment_is_locked(segment: &Path) -> bool {
    let lock = lock_path(segment);
    match fs::read_to_string(&lock) {
        Err(_) if !lock.exists() => false,
        Err(_) => true, // unreadable lock: assume live
        Ok(text) => match text.trim().parse::<u32>() {
            Ok(pid) => pid_alive(pid),
            Err(_) => true, // pid not written yet: assume live
        },
    }
}

/// Stores are per-machine (the lock protocol relies on a shared pid
/// namespace), so /proc is authoritative on Linux; elsewhere be
/// conservative and treat every lock holder as alive.
#[cfg(target_os = "linux")]
fn pid_alive(pid: u32) -> bool {
    Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(not(target_os = "linux"))]
fn pid_alive(_pid: u32) -> bool {
    true
}

/// `(name, length)` of every store file (index + segments) under `dir`,
/// sorted by name — the cheap change detector behind
/// [`ProfileStore::refresh`].  Segments are append-only and compaction
/// replaces whole files, so any new record changes some file's length
/// (or the file set).
fn dir_fingerprint(dir: &Path) -> Result<Vec<(String, u64)>, String> {
    let rd = fs::read_dir(dir)
        .map_err(|e| format!("store: read {}: {e}", dir.display()))?;
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("store: read dir entry: {e}"))?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let ours = name == INDEX_FILE
            || (name.starts_with(SEGMENT_PREFIX)
                && name.ends_with(SEGMENT_SUFFIX));
        if !ours {
            continue;
        }
        // A file deleted mid-scan (racing compaction) counts as length 0;
        // the next pass sees the final state.
        let len = entry.metadata().map(|m| m.len()).unwrap_or(0);
        out.push((name, len));
    }
    out.sort();
    Ok(out)
}

/// All segment files under `dir`, sorted by name.
fn segment_paths(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("store: read {}: {e}", dir.display()))?;
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("store: read dir entry: {e}"))?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with(SEGMENT_PREFIX) && name.ends_with(SEGMENT_SUFFIX) {
            out.push(entry.path());
        }
    }
    out.sort();
    Ok(out)
}

/// Fold every decodable line of `text` into `entries`, tallying skips
/// and v1 migrations.  On duplicate keys the later line wins, except
/// that a CPU-less outcome never displaces a full one (an executor
/// upgrade record must beat the migrated v1 line it upgrades, whichever
/// file loads first).
fn load_lines(
    path: &Path,
    text: &str,
    entries: &mut HashMap<StoreKey, RepOutcome>,
    stats: &mut StoreStats,
) {
    let mut first_bad = true;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match decode_record(line) {
            Ok((key, outcome, ver)) => {
                if ver < STORE_FORMAT_VERSION {
                    stats.migrated_lines += 1;
                }
                match entries.get(&key) {
                    Some(old)
                        if old.cpu_s.is_some() && outcome.cpu_s.is_none() => {}
                    _ => {
                        entries.insert(key, outcome);
                    }
                }
            }
            Err(RecordError::StaleVersion(_)) => stats.stale_lines += 1,
            Err(RecordError::Corrupt(e)) => {
                stats.corrupt_lines += 1;
                if first_bad {
                    first_bad = false;
                    eprintln!(
                        "store: skipping corrupt line(s) in {}: {e}",
                        path.display()
                    );
                }
            }
        }
    }
}

/// Rewrite the index from `entries` via write-to-temp + atomic rename.
/// Must only be called while holding the [`CompactGuard`].
fn write_index(
    dir: &Path,
    entries: &HashMap<StoreKey, RepOutcome>,
) -> Result<(), String> {
    // Sorted lines make the index byte-deterministic: compacting an
    // already-compact store rewrites the identical file (idempotence).
    let mut lines: Vec<String> = entries
        .iter()
        .map(|(k, outcome)| encode_record(k, outcome))
        .collect();
    lines.sort();
    let mut body = lines.join("\n");
    if !body.is_empty() {
        body.push('\n');
    }
    let tmp = dir.join(format!("{INDEX_FILE}.tmp-{}", std::process::id()));
    fs::write(&tmp, body).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    fs::rename(&tmp, dir.join(INDEX_FILE))
        .map_err(|e| format!("rename {}: {e}", tmp.display()))
}

/// Holds `compact.lock` for the duration of one scan-and-rewrite pass.
struct CompactGuard {
    path: PathBuf,
}

impl CompactGuard {
    fn acquire(dir: &Path) -> Option<CompactGuard> {
        let path = dir.join(COMPACT_LOCK);
        for attempt in 0..2 {
            match OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = writeln!(f, "{}", std::process::id());
                    return Some(CompactGuard { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    // A crashed compactor must not disable compaction
                    // forever: reclaim locks far older than any real
                    // pass and retry once.
                    if attempt == 0 && compact_lock_is_stale(&path) {
                        eprintln!(
                            "store: reclaiming stale {}",
                            path.display()
                        );
                        let _ = fs::remove_file(&path);
                        continue;
                    }
                    return None;
                }
                Err(_) => return None,
            }
        }
        None
    }
}

fn compact_lock_is_stale(path: &Path) -> bool {
    fs::metadata(path)
        .and_then(|m| m.modified())
        .ok()
        .and_then(|t| t.elapsed().ok())
        .map(|age| age > STALE_COMPACT_LOCK)
        .unwrap_or(false)
}

impl Drop for CompactGuard {
    fn drop(&mut self) {
        let _ = fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(m: u32, r: u32, rep: u32, seed: u64) -> StoreKey {
        StoreKey {
            cluster: 0xDEAD_BEEF_0BAD_F00D,
            app: AppId::WordCount,
            num_mappers: m,
            num_reducers: r,
            input_gb_bits: StoreKey::PAPER_INPUT_GB.to_bits(),
            block_mb: StoreKey::PAPER_BLOCK_MB,
            rep,
            base_seed: seed,
        }
    }

    /// A record line exactly as the v1 (PR 2) store wrote it.
    fn v1_line(k: &StoreKey, time_s: f64) -> String {
        Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("cluster", Json::Str(hex_u64(k.cluster))),
            ("app", Json::Str(k.app.name().to_string())),
            ("m", Json::Num(k.num_mappers as f64)),
            ("r", Json::Num(k.num_reducers as f64)),
            ("rep", Json::Num(k.rep as f64)),
            ("seed", Json::Str(hex_u64(k.base_seed))),
            ("bits", Json::Str(hex_u64(time_s.to_bits()))),
            ("t", Json::Num(time_s)),
        ])
        .to_string()
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mrtuner_store_unit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn record_round_trips_bit_exactly() {
        for (i, t) in [1523.25, 0.1 + 0.2, f64::MIN_POSITIVE, 1e300].iter().enumerate() {
            let mut k = key(20, 5, i as u32, u64::MAX - i as u64);
            k.input_gb_bits = (1.5 + i as f64).to_bits();
            k.block_mb = 32 << i;
            for outcome in
                [RepOutcome::full(*t, t * 4.0 + 1.0), RepOutcome::time_only(*t)]
            {
                let line = encode_record(&k, &outcome);
                let (k2, o2, ver) = decode_record(&line).unwrap();
                assert_eq!(k2, k);
                assert_eq!(ver, STORE_FORMAT_VERSION);
                assert!(o2.same_bits(&outcome));
            }
        }
    }

    #[test]
    fn decode_classifies_stale_and_corrupt() {
        let line = encode_record(&key(5, 5, 0, 1), &RepOutcome::full(2.0, 3.0));
        let stale = line.replace("\"v\":2", "\"v\":999");
        assert_eq!(
            decode_record(&stale),
            Err(RecordError::StaleVersion(999))
        );
        for bad in ["", "not json", "{\"v\":2}", "{\"v\":1}", "{\"x\":2}", "[1,2,3]"] {
            match decode_record(bad) {
                Err(RecordError::Corrupt(_)) => {}
                other => panic!("expected corrupt for {bad:?}, got {other:?}"),
            }
        }
    }

    #[test]
    fn v1_lines_migrate_to_paper_default_keys() {
        let k = key(20, 5, 3, 42);
        let (k2, o2, ver) = decode_record(&v1_line(&k, 1523.25)).unwrap();
        assert_eq!(ver, 1);
        // The migrated key lands exactly where the 2-parameter executor
        // path keys its reps: the paper-default input/block plane.
        assert_eq!(k2, k);
        assert_eq!(k2.input_gb(), StoreKey::PAPER_INPUT_GB);
        assert_eq!(k2.block_mb, StoreKey::PAPER_BLOCK_MB);
        assert_eq!(o2, RepOutcome::time_only(1523.25));
    }

    #[test]
    fn v1_segment_survives_compaction_and_answers_v2_lookup() {
        let dir = tmp_dir("migrate");
        std::fs::create_dir_all(&dir).unwrap();
        let k = key(20, 5, 0, 7);
        std::fs::write(
            dir.join("seg-cafe0000-0000-legacy.jsonl"),
            format!("{}\n{}\n", v1_line(&k, 100.5), v1_line(&key(20, 5, 1, 7), 101.5)),
        )
        .unwrap();
        {
            let store = ProfileStore::open(&dir).unwrap();
            let st = store.stats();
            assert_eq!(st.migrated_lines, 2);
            assert_eq!(st.merged_segments, 1, "v1 segment folded, not orphaned");
            assert_eq!(st.stale_lines, 0);
            assert_eq!(store.get(&k), Some(RepOutcome::time_only(100.5)));
        }
        // The rewritten index is pure v2 and still answers after reopen.
        let index = std::fs::read_to_string(dir.join(INDEX_FILE)).unwrap();
        assert!(index.contains("\"v\":2"));
        assert!(!index.contains("\"v\":1"));
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.stats().migrated_lines, 0, "migration is one-time");
        assert_eq!(store.get(&k), Some(RepOutcome::time_only(100.5)));
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_outcome_beats_migrated_duplicate_in_any_load_order() {
        let k = key(10, 10, 0, 1);
        let full = RepOutcome::full(55.0, 44.0);
        for lines in [
            // v1-migrated first, upgrade second ...
            format!("{}\n{}\n", v1_line(&k, 55.0), encode_record(&k, &full)),
            // ... and the reverse: the full outcome must win either way.
            format!("{}\n{}\n", encode_record(&k, &full), v1_line(&k, 55.0)),
        ] {
            let mut entries = HashMap::new();
            let mut stats = StoreStats::default();
            load_lines(Path::new("test"), &lines, &mut entries, &mut stats);
            assert_eq!(stats.migrated_lines, 1);
            assert_eq!(entries.get(&k), Some(&full));
        }
    }

    #[test]
    fn put_get_flush_reopen() {
        let dir = tmp_dir("basic");
        {
            let store = ProfileStore::open(&dir).unwrap();
            assert!(store.is_empty());
            store.put(key(20, 5, 0, 42), RepOutcome::full(100.5, 1.25));
            store.put(key(20, 5, 1, 42), RepOutcome::full(101.5, 2.25));
            assert_eq!(store.pending(), 2);
            store.flush().unwrap();
            assert_eq!(store.pending(), 0);
            assert_eq!(
                store.get(&key(20, 5, 0, 42)),
                Some(RepOutcome::full(100.5, 1.25))
            );
        }
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(
            store.get(&key(20, 5, 1, 42)),
            Some(RepOutcome::full(101.5, 2.25))
        );
        assert!(store.get(&key(20, 5, 2, 42)).is_none());
        drop(store);
        assert!(ProfileStore::clear(&dir).unwrap() >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rewriting_known_value_stays_clean() {
        let dir = tmp_dir("rewrite");
        let store = ProfileStore::open(&dir).unwrap();
        store.put(key(5, 5, 0, 7), RepOutcome::full(3.5, 0.5));
        store.flush().unwrap();
        store.put(key(5, 5, 0, 7), RepOutcome::full(3.5, 0.5));
        assert_eq!(store.pending(), 0, "identical value not re-queued");
        // A CPU-less duplicate (migration debris) is not queued either,
        // and does not displace the full outcome.
        store.put(key(5, 5, 0, 7), RepOutcome::time_only(3.5));
        assert_eq!(store.pending(), 0, "downgrade never queued");
        assert_eq!(store.get(&key(5, 5, 0, 7)), Some(RepOutcome::full(3.5, 0.5)));
        // But a full outcome upgrades a CPU-less record in place.
        store.put(key(6, 6, 0, 7), RepOutcome::time_only(9.0));
        store.put(key(6, 6, 0, 7), RepOutcome::full(9.0, 1.0));
        assert_eq!(store.pending(), 2, "upgrade re-queued");
        assert_eq!(store.get(&key(6, 6, 0, 7)), Some(RepOutcome::full(9.0, 1.0)));
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drop_flushes_and_releases_lock() {
        let dir = tmp_dir("droplock");
        {
            let store = ProfileStore::open(&dir).unwrap();
            store.put(key(10, 10, 0, 9), RepOutcome::full(55.0, 5.0));
            store.flush().unwrap();
            // Live session: exactly one lock file present.
            let locks = std::fs::read_dir(&dir)
                .unwrap()
                .filter(|e| {
                    e.as_ref()
                        .unwrap()
                        .file_name()
                        .to_string_lossy()
                        .ends_with(".lock")
                })
                .count();
            assert_eq!(locks, 1);
        }
        let locks = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref()
                    .unwrap()
                    .file_name()
                    .to_string_lossy()
                    .ends_with(".lock")
            })
            .count();
        assert_eq!(locks, 0, "locks released on drop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_of_missing_dir_is_empty() {
        let dir = tmp_dir("missing");
        assert_eq!(ProfileStore::clear(&dir).unwrap(), 0);
    }

    #[test]
    fn generation_counts_disk_and_live_insertions() {
        let dir = tmp_dir("generation");
        {
            let store = ProfileStore::open(&dir).unwrap();
            assert_eq!(store.generation(), 0);
            store.put(key(20, 5, 0, 1), RepOutcome::full(100.0, 1.0));
            store.put(key(20, 5, 1, 1), RepOutcome::full(101.0, 2.0));
            assert_eq!(store.generation(), 2);
            // Re-putting a known value does not advance the generation.
            store.put(key(20, 5, 0, 1), RepOutcome::full(100.0, 1.0));
            assert_eq!(store.generation(), 2);
            store.flush().unwrap();
        }
        // A fresh open seeds the journal with everything on disk.
        let store = ProfileStore::open(&dir).unwrap();
        assert_eq!(store.generation(), 2);
        let (all, generation) = store.read_since(0);
        assert_eq!(generation, 2);
        assert_eq!(all.len(), 2);
        // Sorted by key: rep 0 before rep 1.
        assert_eq!(all[0].0.rep, 0);
        assert_eq!(all[1].0.rep, 1);
        // Tail from the snapshot: nothing new yet.
        let (fresh, g2) = store.read_since(generation);
        assert!(fresh.is_empty());
        assert_eq!(g2, generation);
        store.put(key(30, 5, 0, 1), RepOutcome::full(200.0, 3.0));
        let (fresh, g3) = store.read_since(generation);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].0.num_mappers, 30);
        assert_eq!(g3, 3);
        // A generation past the end is clamped, not a panic.
        assert!(store.read_since(u64::MAX).0.is_empty());
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresh_picks_up_other_sessions_records() {
        let dir = tmp_dir("refresh");
        let reader = ProfileStore::open(&dir).unwrap();
        let before = reader.generation();
        // A concurrent writer session appends and flushes two records.
        {
            let writer = ProfileStore::open(&dir).unwrap();
            writer.put(key(10, 10, 0, 5), RepOutcome::full(50.0, 5.0));
            writer.put(key(10, 10, 1, 5), RepOutcome::full(51.0, 6.0));
            writer.flush().unwrap();
        }
        // Invisible until refresh ...
        assert!(reader.get(&key(10, 10, 0, 5)).is_none());
        assert_eq!(reader.refresh().unwrap(), 2);
        assert_eq!(
            reader.get(&key(10, 10, 0, 5)),
            Some(RepOutcome::full(50.0, 5.0))
        );
        let (fresh, _) = reader.read_since(before);
        assert_eq!(fresh.len(), 2);
        // ... and refreshing again finds nothing new.
        assert_eq!(reader.refresh().unwrap(), 0);
        drop(reader);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresh_never_downgrades_a_full_outcome() {
        let dir = tmp_dir("refresh_downgrade");
        let reader = ProfileStore::open(&dir).unwrap();
        let k = key(15, 15, 0, 9);
        reader.put(k, RepOutcome::full(70.0, 7.0));
        // Another session leaves a CPU-less duplicate on disk (v1 debris).
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("seg-beef0000-0000-dup.jsonl"),
            format!("{}\n", encode_record(&k, &RepOutcome::time_only(70.0))),
        )
        .unwrap();
        assert_eq!(reader.refresh().unwrap(), 0, "downgrade not folded");
        assert_eq!(reader.get(&k), Some(RepOutcome::full(70.0, 7.0)));
        drop(reader);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
