//! Evaluation metrics: the paper's prediction-error statistics.
//!
//! Fig. 3 plots per-experiment relative error between actual and predicted
//! total execution time; Table 1 reports the mean and variance of those
//! percentage errors per application.

use crate::util::stats;

/// Prediction errors for a set of held-out experiments.
#[derive(Clone, Debug)]
pub struct PredictionErrors {
    /// Measured total execution times (seconds).
    pub actual: Vec<f64>,
    /// Model-predicted times (seconds), same order.
    pub predicted: Vec<f64>,
    /// Absolute relative errors in percent: 100·|pred - act| / act.
    pub errors_pct: Vec<f64>,
}

impl PredictionErrors {
    /// Pair up actual and predicted times and compute percent errors.
    pub fn new(actual: Vec<f64>, predicted: Vec<f64>) -> PredictionErrors {
        assert_eq!(actual.len(), predicted.len());
        let errors_pct = actual
            .iter()
            .zip(&predicted)
            .map(|(&a, &p)| {
                assert!(a > 0.0, "actual execution time must be positive");
                100.0 * (p - a).abs() / a
            })
            .collect();
        PredictionErrors { actual, predicted, errors_pct }
    }

    /// Table 1 "Mean (%)".
    pub fn mean_pct(&self) -> f64 {
        stats::mean(&self.errors_pct)
    }

    /// Table 1 "Variance (%)": population variance of the percent errors.
    pub fn variance_pct(&self) -> f64 {
        stats::variance(&self.errors_pct)
    }

    /// Median percent error (robust companion to the mean).
    pub fn median_pct(&self) -> f64 {
        stats::percentile(&self.errors_pct, 50.0)
    }

    /// Worst-case percent error.
    pub fn max_pct(&self) -> f64 {
        stats::max(&self.errors_pct)
    }

    /// Coefficient of determination between actual and predicted times.
    pub fn r_squared(&self) -> f64 {
        stats::r_squared(&self.actual, &self.predicted)
    }

    /// Number of held-out experiments evaluated.
    pub fn len(&self) -> usize {
        self.errors_pct.len()
    }

    /// Whether no experiments were evaluated.
    pub fn is_empty(&self) -> bool {
        self.errors_pct.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction_zero_error() {
        let e = PredictionErrors::new(vec![100.0, 200.0], vec![100.0, 200.0]);
        assert_eq!(e.mean_pct(), 0.0);
        assert_eq!(e.variance_pct(), 0.0);
        assert_eq!(e.r_squared(), 1.0);
    }

    #[test]
    fn known_errors() {
        // +5% and -10% -> abs errors 5 and 10.
        let e = PredictionErrors::new(vec![100.0, 200.0], vec![105.0, 180.0]);
        assert_eq!(e.errors_pct, vec![5.0, 10.0]);
        assert_eq!(e.mean_pct(), 7.5);
        assert_eq!(e.variance_pct(), 6.25);
        assert_eq!(e.median_pct(), 7.5);
        assert_eq!(e.max_pct(), 10.0);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_nonpositive_actuals() {
        PredictionErrors::new(vec![0.0], vec![1.0]);
    }
}
