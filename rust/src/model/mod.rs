//! Modeling phase — the paper's Eqns. 1-6.
//!
//! [`features`] builds the per-parameter-cubic design matrix (Eqn. 2);
//! [`solver`] solves the weighted normal equations in pure Rust (Cholesky)
//! — the baseline/cross-check backend; [`regression`] wraps fit/predict
//! behind a backend trait so the production path can swap in the PJRT
//! artifact executor ([`crate::runtime`]); [`metrics`] computes the
//! paper's evaluation statistics (Fig. 3 errors, Table 1 moments);
//! [`target`] names the modeled outputs (time / CPU / shuffle bytes) the
//! online trainer fits one regression per app for.

pub mod features;
pub mod metrics;
pub mod mlp;
pub mod ndpoly;
pub mod regression;
pub mod solver;
pub mod target;

pub use features::{expand_row, expand_rows, NUM_FEATURES, PARAM_SCALE};
pub use metrics::PredictionErrors;
pub use regression::{FitBackend, RegressionModel, RustSolverBackend};
pub use target::Target;
