//! Nonlinear execution-time model — the paper's own suggestion:
//! "To be more precise, it is better to use nonlinear modeling techniques
//! like neural network" (§III).
//!
//! A small fully-connected network (2 → H → H → 1, tanh) trained with
//! Adam on normalized parameters and standardized targets.  Deterministic
//! given the seed.  Quantified against the cubic in
//! `rust/benches/ablation.rs` — on the paper's smooth surface the cubic
//! is already near the noise floor, which is the honest counterpoint to
//! the paper's suggestion.

use crate::util::rng::Rng;

use super::features::PARAM_SCALE;

/// Training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct MlpConfig {
    /// Hidden-layer width.
    pub hidden: usize,
    /// Full-batch gradient-descent epochs.
    pub epochs: u32,
    /// Learning rate.
    pub lr: f64,
    /// Weight-init RNG seed.
    pub seed: u64,
}

impl Default for MlpConfig {
    fn default() -> Self {
        MlpConfig { hidden: 16, epochs: 3000, lr: 0.01, seed: 0 }
    }
}

/// A trained network.
#[derive(Clone, Debug)]
pub struct MlpModel {
    /// Application this network was trained for.
    pub app_name: String,
    hidden: usize,
    // Layer weights (row-major) and biases.
    w1: Vec<f64>, // hidden x 2
    b1: Vec<f64>,
    w2: Vec<f64>, // hidden x hidden
    b2: Vec<f64>,
    w3: Vec<f64>, // 1 x hidden
    b3: f64,
    // Target standardization.
    t_mean: f64,
    t_std: f64,
}

struct Grads {
    w1: Vec<f64>,
    b1: Vec<f64>,
    w2: Vec<f64>,
    b2: Vec<f64>,
    w3: Vec<f64>,
    b3: f64,
}

impl MlpModel {
    /// Train on raw (M, R) rows and execution times.
    pub fn fit(
        app_name: &str,
        params: &[[f64; 2]],
        times: &[f64],
        config: MlpConfig,
    ) -> Result<MlpModel, String> {
        if params.is_empty() || params.len() != times.len() {
            return Err("bad training set".into());
        }
        let h = config.hidden;
        let n = params.len();
        let mut rng = Rng::new(config.seed ^ 0x6d6c_705f_696e_6974);

        // Standardize targets (tanh nets train poorly on ~600s raw scale).
        let t_mean = times.iter().sum::<f64>() / n as f64;
        let t_std = (times.iter().map(|t| (t - t_mean).powi(2)).sum::<f64>()
            / n as f64)
            .sqrt()
            .max(1e-9);
        let targets: Vec<f64> = times.iter().map(|t| (t - t_mean) / t_std).collect();
        let inputs: Vec<[f64; 2]> = params
            .iter()
            .map(|p| [p[0] / PARAM_SCALE, p[1] / PARAM_SCALE])
            .collect();

        // Xavier-ish init.
        let mut init = |fan_in: usize, count: usize| -> Vec<f64> {
            let s = (1.0 / fan_in as f64).sqrt();
            (0..count).map(|_| rng.normal_ms(0.0, s)).collect()
        };
        let mut model = MlpModel {
            app_name: app_name.to_string(),
            hidden: h,
            w1: init(2, h * 2),
            b1: vec![0.0; h],
            w2: init(h, h * h),
            b2: vec![0.0; h],
            w3: init(h, h),
            b3: 0.0,
            t_mean,
            t_std,
        };

        // Adam state.
        let sz = |v: &Vec<f64>| vec![0.0; v.len()];
        let (mut m1, mut v1) = (sz(&model.w1), sz(&model.w1));
        let (mut mb1, mut vb1) = (sz(&model.b1), sz(&model.b1));
        let (mut m2, mut v2) = (sz(&model.w2), sz(&model.w2));
        let (mut mb2, mut vb2) = (sz(&model.b2), sz(&model.b2));
        let (mut m3, mut v3) = (sz(&model.w3), sz(&model.w3));
        let (mut mb3, mut vb3) = (0.0f64, 0.0f64);
        let (beta1, beta2, eps): (f64, f64, f64) = (0.9, 0.999, 1e-8);

        for epoch in 1..=config.epochs {
            let g = model.batch_grads(&inputs, &targets);
            let t = epoch as f64;
            let bc1 = 1.0 - beta1.powf(t);
            let bc2 = 1.0 - beta2.powf(t);
            let adam = |w: &mut [f64], g: &[f64], m: &mut [f64], v: &mut [f64]| {
                for i in 0..w.len() {
                    m[i] = beta1 * m[i] + (1.0 - beta1) * g[i];
                    v[i] = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
                    w[i] -= config.lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
                }
            };
            adam(&mut model.w1, &g.w1, &mut m1, &mut v1);
            adam(&mut model.b1, &g.b1, &mut mb1, &mut vb1);
            adam(&mut model.w2, &g.w2, &mut m2, &mut v2);
            adam(&mut model.b2, &g.b2, &mut mb2, &mut vb2);
            adam(&mut model.w3, &g.w3, &mut m3, &mut v3);
            mb3 = beta1 * mb3 + (1.0 - beta1) * g.b3;
            vb3 = beta2 * vb3 + (1.0 - beta2) * g.b3 * g.b3;
            model.b3 -= config.lr * (mb3 / bc1) / ((vb3 / bc2).sqrt() + eps);
        }
        Ok(model)
    }

    /// Forward pass on normalized input, standardized output.
    fn forward(&self, x: &[f64; 2]) -> (Vec<f64>, Vec<f64>, f64) {
        let h = self.hidden;
        let mut a1 = vec![0.0; h];
        for i in 0..h {
            a1[i] = (self.w1[i * 2] * x[0] + self.w1[i * 2 + 1] * x[1]
                + self.b1[i])
                .tanh();
        }
        let mut a2 = vec![0.0; h];
        for i in 0..h {
            let mut s = self.b2[i];
            for j in 0..h {
                s += self.w2[i * h + j] * a1[j];
            }
            a2[i] = s.tanh();
        }
        let mut out = self.b3;
        for j in 0..h {
            out += self.w3[j] * a2[j];
        }
        (a1, a2, out)
    }

    fn batch_grads(&self, inputs: &[[f64; 2]], targets: &[f64]) -> Grads {
        let h = self.hidden;
        let n = inputs.len() as f64;
        let mut g = Grads {
            w1: vec![0.0; h * 2],
            b1: vec![0.0; h],
            w2: vec![0.0; h * h],
            b2: vec![0.0; h],
            w3: vec![0.0; h],
            b3: 0.0,
        };
        for (x, &t) in inputs.iter().zip(targets) {
            let (a1, a2, out) = self.forward(x);
            let dout = 2.0 * (out - t) / n; // d(MSE)/d(out)
            g.b3 += dout;
            let mut da2 = vec![0.0; h];
            for j in 0..h {
                g.w3[j] += dout * a2[j];
                da2[j] = dout * self.w3[j] * (1.0 - a2[j] * a2[j]);
            }
            let mut da1 = vec![0.0; h];
            for i in 0..h {
                g.b2[i] += da2[i];
                for j in 0..h {
                    g.w2[i * h + j] += da2[i] * a1[j];
                    da1[j] += da2[i] * self.w2[i * h + j];
                }
            }
            for j in 0..h {
                let d = da1[j] * (1.0 - a1[j] * a1[j]);
                g.b1[j] += d;
                g.w1[j * 2] += d * x[0];
                g.w1[j * 2 + 1] += d * x[1];
            }
        }
        g
    }

    /// Predict a raw (M, R) setting in seconds.
    pub fn predict_one(&self, num_mappers: u32, num_reducers: u32) -> f64 {
        let x = [
            num_mappers as f64 / PARAM_SCALE,
            num_reducers as f64 / PARAM_SCALE,
        ];
        let (_, _, out) = self.forward(&x);
        out * self.t_std + self.t_mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_config(seed: u64) -> MlpConfig {
        MlpConfig { hidden: 12, epochs: 1500, lr: 0.02, seed }
    }

    fn surface(m: f64, r: f64) -> f64 {
        let x = m / 40.0;
        let y = r / 40.0;
        500.0 - 120.0 * x + 90.0 * x * x + 60.0 * y * y
    }

    fn grid() -> (Vec<[f64; 2]>, Vec<f64>) {
        let mut params = Vec::new();
        let mut times = Vec::new();
        for m in (5..=40).step_by(5) {
            for r in (5..=40).step_by(5) {
                params.push([m as f64, r as f64]);
                times.push(surface(m as f64, r as f64));
            }
        }
        (params, times)
    }

    #[test]
    fn learns_a_smooth_surface() {
        let (params, times) = grid();
        let model =
            MlpModel::fit("wc", &params, &times, quick_config(1)).unwrap();
        let mut errs = Vec::new();
        for (m, r) in [(7, 12), (22, 33), (38, 8), (13, 26)] {
            let pred = model.predict_one(m, r);
            let truth = surface(m as f64, r as f64);
            errs.push((pred - truth).abs() / truth);
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean_err < 0.05, "mlp mean error {mean_err:.4}");
    }

    #[test]
    fn deterministic_for_seed() {
        let (params, times) = grid();
        let a = MlpModel::fit("x", &params, &times, quick_config(7)).unwrap();
        let b = MlpModel::fit("x", &params, &times, quick_config(7)).unwrap();
        assert_eq!(a.predict_one(20, 5), b.predict_one(20, 5));
        let c = MlpModel::fit("x", &params, &times, quick_config(8)).unwrap();
        assert_ne!(a.predict_one(20, 5), c.predict_one(20, 5));
    }

    #[test]
    fn rejects_empty() {
        assert!(MlpModel::fit("x", &[], &[], MlpConfig::default()).is_err());
        assert!(
            MlpModel::fit("x", &[[1.0, 2.0]], &[], MlpConfig::default()).is_err()
        );
    }

    #[test]
    fn output_in_target_scale() {
        let (params, times) = grid();
        let model =
            MlpModel::fit("x", &params, &times, quick_config(2)).unwrap();
        let p = model.predict_one(20, 20);
        assert!(p > 300.0 && p < 800.0, "prediction {p} off the target scale");
    }
}
