//! N-parameter polynomial regression — the paper's §I extension hook
//! ("the proposed modeling technique can be extended for other
//! configuration parameters") and its companion work [24], which models
//! four MapReduce parameters: number of mappers, number of reducers,
//! file-system (block) size and input-file size.
//!
//! Features follow Eqn. 2 generalized: `[1, p1..p1^d, ..., pN..pN^d]`
//! with per-parameter normalization scales.  The solver is the same
//! ridge-stabilized Cholesky as the 2-parameter production path.

use crate::util::json::Json;

/// A fitted N-parameter, degree-`d` polynomial model.
///
/// `interactions` optionally appends pairwise products `x_i * x_j` of the
/// normalized first powers.  The paper's Eqn. 2 basis is purely additive
/// per-parameter — which cannot express e.g. the input_size x block_size
/// coupling that determines map-task count; the extensions bench
/// quantifies the gap.
#[derive(Clone, Debug, PartialEq)]
pub struct NdPolyModel {
    /// Application this model was trained for.
    pub app_name: String,
    /// Polynomial degree per parameter.
    pub degree: usize,
    /// Per-parameter normalization divisors (max of the studied range).
    pub scales: Vec<f64>,
    /// Whether pairwise interaction terms are appended.
    pub interactions: bool,
    /// Fitted coefficients, [`NdPolyModel::num_features`] long.
    pub coeffs: Vec<f64>,
}

impl NdPolyModel {
    /// Number of raw parameters this model takes.
    pub fn num_params(&self) -> usize {
        self.scales.len()
    }

    /// Expanded feature count for `num_params` raw parameters at
    /// `degree`, with or without pairwise interactions — the one formula
    /// shared by fitting validation and callers sizing training sets.
    pub fn feature_count(
        num_params: usize,
        degree: usize,
        interactions: bool,
    ) -> usize {
        1 + num_params * degree
            + if interactions { num_params * (num_params - 1) / 2 } else { 0 }
    }

    /// Length of the expanded feature vector.
    pub fn num_features(&self) -> usize {
        NdPolyModel::feature_count(self.num_params(), self.degree, self.interactions)
    }

    /// Expand one raw parameter row into the feature vector.
    pub fn expand(&self, params: &[f64]) -> Vec<f64> {
        expand(params, &self.scales, self.degree, self.interactions)
    }

    /// Fit the paper's additive basis (Eqn. 2 generalized).
    pub fn fit(
        app_name: &str,
        rows: &[Vec<f64>],
        times: &[f64],
        weights: &[f64],
        degree: usize,
        scales: &[f64],
    ) -> Result<NdPolyModel, String> {
        Self::fit_opts(app_name, rows, times, weights, degree, scales, false)
    }

    /// Fit with optional pairwise interaction features.
    pub fn fit_opts(
        app_name: &str,
        rows: &[Vec<f64>],
        times: &[f64],
        weights: &[f64],
        degree: usize,
        scales: &[f64],
        interactions: bool,
    ) -> Result<NdPolyModel, String> {
        if rows.is_empty() {
            return Err("empty training set".into());
        }
        if rows.len() != times.len() || rows.len() != weights.len() {
            return Err("rows/times/weights length mismatch".into());
        }
        let n = scales.len();
        if rows.iter().any(|r| r.len() != n) {
            return Err(format!("every row must have {n} parameters"));
        }
        if scales.iter().any(|&s| s <= 0.0) {
            return Err("scales must be positive".into());
        }
        let f = NdPolyModel::feature_count(n, degree, interactions);
        if rows.len() < f {
            return Err(format!(
                "need at least {f} rows for {f} features, got {}",
                rows.len()
            ));
        }
        let x: Vec<Vec<f64>> = rows
            .iter()
            .map(|r| expand(r, scales, degree, interactions))
            .collect();
        let coeffs = solve_weighted(&x, times, weights, f)?;
        Ok(NdPolyModel {
            app_name: app_name.to_string(),
            degree,
            scales: scales.to_vec(),
            interactions,
            coeffs,
        })
    }

    /// Predict one raw parameter row (Eqn. 5).
    pub fn predict_one(&self, params: &[f64]) -> f64 {
        self.expand(params)
            .iter()
            .zip(&self.coeffs)
            .map(|(a, b)| a * b)
            .sum()
    }

    /// Predict a batch of raw parameter rows.
    pub fn predict(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict_one(r)).collect()
    }

    /// Serialize for persistence.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", Json::Str(self.app_name.clone())),
            ("degree", Json::Num(self.degree as f64)),
            ("scales", Json::from_f64_slice(&self.scales)),
            ("interactions", Json::Bool(self.interactions)),
            ("coeffs", Json::from_f64_slice(&self.coeffs)),
        ])
    }

    /// Rebuild from [`NdPolyModel::to_json`] output.
    pub fn from_json(v: &Json) -> Result<NdPolyModel, String> {
        let m = NdPolyModel {
            app_name: v.req("app")?.as_str().ok_or("app")?.to_string(),
            degree: v.req("degree")?.as_u64().ok_or("degree")? as usize,
            scales: v.req("scales")?.to_f64_vec()?,
            interactions: v
                .get("interactions")
                .and_then(|b| b.as_bool())
                .unwrap_or(false),
            coeffs: v.req("coeffs")?.to_f64_vec()?,
        };
        if m.coeffs.len() != m.num_features() {
            return Err(format!(
                "coeff count {} != features {}",
                m.coeffs.len(),
                m.num_features()
            ));
        }
        Ok(m)
    }
}

fn expand(
    params: &[f64],
    scales: &[f64],
    degree: usize,
    interactions: bool,
) -> Vec<f64> {
    debug_assert_eq!(params.len(), scales.len());
    let n = params.len();
    let mut out = Vec::with_capacity(1 + n * degree + n * (n - 1) / 2);
    out.push(1.0);
    let norm: Vec<f64> =
        params.iter().zip(scales).map(|(&p, &s)| p / s).collect();
    for &x in &norm {
        let mut pow = 1.0;
        for _ in 0..degree {
            pow *= x;
            out.push(pow);
        }
    }
    if interactions {
        for i in 0..n {
            for j in i + 1..n {
                out.push(norm[i] * norm[j]);
            }
        }
    }
    out
}

/// Weighted normal equations + ridge + dynamic Cholesky.
fn solve_weighted(
    x: &[Vec<f64>],
    t: &[f64],
    w: &[f64],
    f: usize,
) -> Result<Vec<f64>, String> {
    let mut g = vec![vec![0.0; f]; f];
    let mut b = vec![0.0; f];
    for ((row, &wi), &ti) in x.iter().zip(w).zip(t) {
        for i in 0..f {
            let wxi = wi * row[i];
            b[i] += wxi * ti;
            for j in i..f {
                g[i][j] += wxi * row[j];
            }
        }
    }
    for i in 0..f {
        for j in 0..i {
            g[i][j] = g[j][i];
        }
    }
    let trace: f64 = (0..f).map(|i| g[i][i]).sum();
    if trace <= 0.0 {
        return Err("all-zero system".into());
    }
    let mut lam = super::solver::RIDGE_REL * trace / f as f64;
    for _ in 0..10 {
        for i in 0..f {
            g[i][i] += lam;
        }
        if let Some(sol) = try_cholesky(&g, &b, f) {
            return Ok(sol);
        }
        lam = (lam * 100.0).max(1e-10);
    }
    Err("not positive definite even with ridge".into())
}

fn try_cholesky(g: &[Vec<f64>], b: &[f64], f: usize) -> Option<Vec<f64>> {
    let mut l = g.to_vec();
    for i in 0..f {
        for j in 0..=i {
            let mut s = l[i][j];
            for k in 0..j {
                s -= l[i][k] * l[j][k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i][j] = s.sqrt();
            } else {
                l[i][j] = s / l[j][j];
            }
        }
    }
    let mut y = vec![0.0; f];
    for i in 0..f {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i][k] * y[k];
        }
        y[i] = s / l[i][i];
    }
    let mut x = vec![0.0; f];
    for i in (0..f).rev() {
        let mut s = y[i];
        for k in i + 1..f {
            s -= l[k][i] * x[k];
        }
        x[i] = s / l[i][i];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn surface4(p: &[f64]) -> f64 {
        // In-family degree-3 surface over 4 normalized params.
        let x: Vec<f64> = p
            .iter()
            .zip(&[40.0, 40.0, 16.0, 256.0])
            .map(|(v, s)| v / s)
            .collect();
        100.0 + 50.0 * x[0] - 30.0 * x[0].powi(2) + 20.0 * x[1]
            + 400.0 * x[2]
            + 35.0 * x[2].powi(3)
            - 25.0 * x[3]
            + 10.0 * x[3].powi(2)
    }

    fn sample4(rng: &mut Rng, n: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                vec![
                    rng.range_u64(5, 41) as f64,
                    rng.range_u64(5, 41) as f64,
                    rng.range_u64(1, 17) as f64,
                    rng.range_u64(32, 257) as f64,
                ]
            })
            .collect();
        let times = rows.iter().map(|r| surface4(r)).collect();
        (rows, times)
    }

    const SCALES: [f64; 4] = [40.0, 40.0, 16.0, 256.0];

    #[test]
    fn recovers_in_family_4d_surface() {
        let mut rng = Rng::new(1);
        let (rows, times) = sample4(&mut rng, 60);
        let w = vec![1.0; 60];
        let m = NdPolyModel::fit("x", &rows, &times, &w, 3, &SCALES).unwrap();
        assert_eq!(m.num_features(), 13);
        let (test, truth) = sample4(&mut rng, 30);
        for (r, &t) in test.iter().zip(&truth) {
            let pred = m.predict_one(r);
            assert!((pred - t).abs() / t.abs() < 1e-5, "{pred} vs {t}");
        }
    }

    #[test]
    fn two_param_case_matches_fixed_solver() {
        let mut rng = Rng::new(2);
        let rows: Vec<Vec<f64>> = (0..30)
            .map(|_| vec![rng.range_u64(5, 41) as f64, rng.range_u64(5, 41) as f64])
            .collect();
        let times: Vec<f64> = rows
            .iter()
            .map(|r| 300.0 + 2.0 * r[0] + 0.05 * r[0] * r[0] + 3.0 * r[1])
            .collect();
        let w = vec![1.0; 30];
        let nd = NdPolyModel::fit("x", &rows, &times, &w, 3, &[40.0, 40.0]).unwrap();
        let pairs: Vec<[f64; 2]> = rows.iter().map(|r| [r[0], r[1]]).collect();
        let fixed = crate::model::solver::fit(&pairs, &times, &w).unwrap();
        for i in 0..7 {
            assert!((nd.coeffs[i] - fixed[i]).abs() < 1e-8, "coeff {i}");
        }
    }

    #[test]
    fn validation_errors() {
        let rows = vec![vec![1.0, 2.0]];
        assert!(NdPolyModel::fit("x", &[], &[], &[], 3, &[1.0]).is_err());
        assert!(
            NdPolyModel::fit("x", &rows, &[1.0], &[1.0], 3, &[1.0]).is_err(),
            "row width mismatch"
        );
        assert!(
            NdPolyModel::fit("x", &rows, &[1.0], &[1.0], 3, &[1.0, -2.0]).is_err(),
            "negative scale"
        );
        // Too few rows for 7 features.
        let rows2: Vec<Vec<f64>> = (0..3).map(|i| vec![i as f64, 1.0]).collect();
        assert!(NdPolyModel::fit(
            "x",
            &rows2,
            &[1.0, 2.0, 3.0],
            &[1.0, 1.0, 1.0],
            3,
            &[1.0, 1.0]
        )
        .is_err());
    }

    #[test]
    fn json_round_trip() {
        let mut rng = Rng::new(3);
        let (rows, times) = sample4(&mut rng, 40);
        let m = NdPolyModel::fit("wc", &rows, &times, &vec![1.0; 40], 2, &SCALES)
            .unwrap();
        let back = NdPolyModel::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn prop_weighted_padding_invariance() {
        forall("ndpoly padding", 10, |rng| {
            let (mut rows, mut times) = sample4(rng, 40);
            let mut w = vec![1.0; 40];
            let clean =
                NdPolyModel::fit("x", &rows, &times, &w, 3, &SCALES).unwrap();
            // Garbage rows with zero weight change nothing.
            rows.push(vec![1e9, -5.0, 0.0, 1.0]);
            times.push(1e15);
            w.push(0.0);
            let padded =
                NdPolyModel::fit("x", &rows, &times, &w, 3, &SCALES).unwrap();
            for i in 0..clean.coeffs.len() {
                let scale = clean.coeffs[i].abs().max(1.0);
                assert!((clean.coeffs[i] - padded.coeffs[i]).abs() / scale < 1e-8);
            }
        });
    }
}
