//! Prediction targets: which modeled output a regression fits.
//!
//! The source paper (arXiv 1203.0651) regresses **total execution time**
//! against the `(M, R)` configuration plane; its companion works apply
//! the identical methodology to **total CPU seconds** (arXiv 1203.4054)
//! and to **shuffle/network load** (arXiv 1206.2016).  All three fit the
//! same per-parameter-cubic feature basis through the same
//! [`super::regression::FitAccumulator`] — only the dependent variable
//! changes — so a target is just a selector over [`RepOutcome`] plus a
//! naming convention for the published model.

use crate::mr::RepOutcome;

/// One modeled output of a repetition — the dependent variable of one
/// per-app regression.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Target {
    /// Total execution time in seconds — the source paper's T.
    TimeS,
    /// Total CPU seconds (arXiv 1203.4054's "CPU tick clocks").
    CpuS,
    /// Shuffle bytes (arXiv 1206.2016's network-load target).
    ShuffleBytes,
}

impl Target {
    /// Every target, in fit/publish order.  `TimeS` first: it is the
    /// paper's target and the legacy single-target serving path.
    pub fn all() -> [Target; 3] {
        [Target::TimeS, Target::CpuS, Target::ShuffleBytes]
    }

    /// Stable wire/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            Target::TimeS => "time_s",
            Target::CpuS => "cpu_s",
            Target::ShuffleBytes => "shuffle_bytes",
        }
    }

    /// Inverse of [`Target::name`].
    pub fn parse(s: &str) -> Result<Target, String> {
        match s {
            "time_s" => Ok(Target::TimeS),
            "cpu_s" => Ok(Target::CpuS),
            "shuffle_bytes" => Ok(Target::ShuffleBytes),
            other => Err(format!(
                "unknown target '{other}' (expected time_s | cpu_s | \
                 shuffle_bytes)"
            )),
        }
    }

    /// This target's value in one repetition outcome, if recorded.
    /// `TimeS` is always present; the others are absent on records
    /// migrated from older store formats (and on quarantine sentinels).
    pub fn value(&self, o: &RepOutcome) -> Option<f64> {
        match self {
            Target::TimeS => Some(o.time_s),
            Target::CpuS => o.cpu_s,
            Target::ShuffleBytes => o.bytes.map(|b| b.shuffle as f64),
        }
    }

    /// Registry/wire name of `app`'s model for this target.
    ///
    /// `TimeS` maps to the **plain app name** — the name every pre-
    /// multi-target client already predicts against — so legacy
    /// single-target `predict` resolves the identical registry entry,
    /// bit-identically.  Other targets qualify as `app@target`.
    pub fn qualified(&self, app: &str) -> String {
        match self {
            Target::TimeS => app.to_string(),
            other => format!("{app}@{}", other.name()),
        }
    }
}

impl std::fmt::Display for Target {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mr::RepBytes;

    #[test]
    fn names_round_trip() {
        for t in Target::all() {
            assert_eq!(Target::parse(t.name()), Ok(t));
            assert_eq!(format!("{t}"), t.name());
        }
        assert!(Target::parse("makespan").is_err());
    }

    #[test]
    fn values_select_the_recorded_figure() {
        let full = RepOutcome::with_bytes(
            10.0,
            20.0,
            RepBytes { shuffle: 1 << 20, hdfs: 1 << 21 },
        );
        assert_eq!(Target::TimeS.value(&full), Some(10.0));
        assert_eq!(Target::CpuS.value(&full), Some(20.0));
        assert_eq!(
            Target::ShuffleBytes.value(&full),
            Some((1u64 << 20) as f64)
        );
        let v1 = RepOutcome::time_only(3.0);
        assert_eq!(Target::TimeS.value(&v1), Some(3.0));
        assert_eq!(Target::CpuS.value(&v1), None);
        assert_eq!(Target::ShuffleBytes.value(&v1), None);
    }

    #[test]
    fn time_target_keeps_the_legacy_model_name() {
        assert_eq!(Target::TimeS.qualified("wordcount"), "wordcount");
        assert_eq!(Target::CpuS.qualified("grep"), "grep@cpu_s");
        assert_eq!(
            Target::ShuffleBytes.qualified("sort"),
            "sort@shuffle_bytes"
        );
    }
}
