//! Cubic polynomial feature expansion (paper Eqn. 2) — Rust mirror of the
//! Pallas kernel in `python/compile/kernels/poly_features.py`.
//!
//! Must stay bit-compatible in *semantics* with the Python side: same
//! normalization constant, same feature order `[1, p1, p1², p1³, p2, p2²,
//! p2³]`.  The Rust runtime asserts both sides agree via the artifact
//! manifest, and `rust/tests/` cross-checks numerics through PJRT.

/// Features per row: intercept + 3 powers × 2 parameters.
pub const NUM_FEATURES: usize = 7;

/// Parameter normalization: raw mapper/reducer counts divide by the
/// paper's maximum setting (40) before expansion, keeping the cubic Gram
/// matrix well-conditioned.  Identical constant on the Python side.
pub const PARAM_SCALE: f64 = 40.0;

/// Expand one raw `(num_mappers, num_reducers)` row.
pub fn expand_row(params: &[f64; 2]) -> [f64; NUM_FEATURES] {
    let p1 = params[0] / PARAM_SCALE;
    let p2 = params[1] / PARAM_SCALE;
    [1.0, p1, p1 * p1, p1 * p1 * p1, p2, p2 * p2, p2 * p2 * p2]
}

/// Expand a batch of rows into a row-major design matrix.
pub fn expand_rows(params: &[[f64; 2]]) -> Vec<[f64; NUM_FEATURES]> {
    params.iter().map(expand_row).collect()
}

/// Evaluate the fitted polynomial (paper Eqn. 5) for one row.
pub fn evaluate(coeffs: &[f64; NUM_FEATURES], params: &[f64; 2]) -> f64 {
    let x = expand_row(params);
    x.iter().zip(coeffs).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn feature_order_matches_paper_eqn2() {
        let f = expand_row(&[20.0, 10.0]);
        assert_eq!(f[0], 1.0);
        assert_eq!(f[1], 0.5);
        assert_eq!(f[2], 0.25);
        assert_eq!(f[3], 0.125);
        assert_eq!(f[4], 0.25);
        assert_eq!(f[5], 0.0625);
        assert_eq!(f[6], 0.015625);
    }

    #[test]
    fn scale_boundary_is_all_ones() {
        let f = expand_row(&[40.0, 40.0]);
        assert_eq!(f, [1.0; 7]);
    }

    #[test]
    fn evaluate_is_dot_product() {
        let coeffs = [2.0, 1.0, 0.0, 0.0, -1.0, 0.0, 0.0];
        // 2 + p1 - p2 with p = (20, 40)/40 = (0.5, 1.0)
        assert_eq!(evaluate(&coeffs, &[20.0, 40.0]), 1.5);
    }

    #[test]
    fn prop_powers_consistent() {
        forall("feature powers", 50, |rng| {
            let p = [rng.range_f64(1.0, 64.0), rng.range_f64(1.0, 64.0)];
            let f = expand_row(&p);
            assert!((f[2] - f[1] * f[1]).abs() < 1e-15);
            assert!((f[3] - f[1] * f[2]).abs() < 1e-15);
            assert!((f[5] - f[4] * f[4]).abs() < 1e-15);
            assert!((f[6] - f[4] * f[5]).abs() < 1e-15);
        });
    }

    #[test]
    fn batch_matches_single() {
        let rows = [[5.0, 40.0], [17.0, 23.0]];
        let batch = expand_rows(&rows);
        assert_eq!(batch[0], expand_row(&rows[0]));
        assert_eq!(batch[1], expand_row(&rows[1]));
    }
}
