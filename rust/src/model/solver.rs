//! Pure-Rust weighted least squares via normal equations + Cholesky.
//!
//! This is the *baseline* backend (and the cross-check for the PJRT
//! artifact): it implements exactly the math of `python/compile/model.py`
//! — weighted Gram assembly, relative ridge, dense solve — so the two
//! backends must agree to ~1e-9 relative, which `rust/tests/` asserts.

use super::features::{expand_rows, NUM_FEATURES};

/// Relative ridge, identical to `model.RIDGE_REL` on the Python side.
pub const RIDGE_REL: f64 = 1e-9;

/// Assemble the weighted normal-equation system G = XᵀWX, b = Xᵀ(w∘t).
pub fn gram_system(
    x: &[[f64; NUM_FEATURES]],
    w: &[f64],
    t: &[f64],
) -> ([[f64; NUM_FEATURES]; NUM_FEATURES], [f64; NUM_FEATURES]) {
    assert_eq!(x.len(), w.len());
    assert_eq!(x.len(), t.len());
    let mut g = [[0.0; NUM_FEATURES]; NUM_FEATURES];
    let mut b = [0.0; NUM_FEATURES];
    for ((row, &wi), &ti) in x.iter().zip(w).zip(t) {
        for i in 0..NUM_FEATURES {
            let wxi = wi * row[i];
            b[i] += wxi * ti;
            for j in i..NUM_FEATURES {
                g[i][j] += wxi * row[j];
            }
        }
    }
    // Mirror the upper triangle.
    for i in 0..NUM_FEATURES {
        for j in 0..i {
            g[i][j] = g[j][i];
        }
    }
    (g, b)
}

/// Cholesky factorization (in place, lower triangle).  Returns false if
/// the matrix is not positive definite.
fn cholesky(a: &mut [[f64; NUM_FEATURES]; NUM_FEATURES]) -> bool {
    for i in 0..NUM_FEATURES {
        for j in 0..=i {
            let mut sum = a[i][j];
            for k in 0..j {
                sum -= a[i][k] * a[j][k];
            }
            if i == j {
                if sum <= 0.0 {
                    return false;
                }
                a[i][j] = sum.sqrt();
            } else {
                a[i][j] = sum / a[j][j];
            }
        }
    }
    true
}

/// Solve `L Lᵀ x = b` given the Cholesky factor in the lower triangle.
fn cholesky_solve(
    l: &[[f64; NUM_FEATURES]; NUM_FEATURES],
    b: &[f64; NUM_FEATURES],
) -> [f64; NUM_FEATURES] {
    let mut y = [0.0; NUM_FEATURES];
    for i in 0..NUM_FEATURES {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i][k] * y[k];
        }
        y[i] = s / l[i][i];
    }
    let mut x = [0.0; NUM_FEATURES];
    for i in (0..NUM_FEATURES).rev() {
        let mut s = y[i];
        for k in i + 1..NUM_FEATURES {
            s -= l[k][i] * x[k];
        }
        x[i] = s / l[i][i];
    }
    x
}

/// Weighted cubic-basis least squares (paper Eqn. 6 + relative ridge).
///
/// `params`: raw (M, R) rows; `times`: observed totals; `weights`: >= 0,
/// zero marks ignored rows.  Returns the 7 coefficients over the
/// normalized basis, or an error for hopelessly singular systems.
pub fn fit(
    params: &[[f64; 2]],
    times: &[f64],
    weights: &[f64],
) -> Result<[f64; NUM_FEATURES], String> {
    let x = expand_rows(params);
    let (g, b) = gram_system(&x, weights, times);
    solve_gram(g, b)
}

/// Solve an assembled normal-equation system with the production ridge
/// policy (relative ridge, escalated on Cholesky failure) — the shared
/// back half of [`fit`] and the incremental
/// [`crate::model::regression::FitAccumulator`] path, so batch and
/// incremental fits of the same Gram are bit-identical by construction.
pub fn solve_gram(
    mut g: [[f64; NUM_FEATURES]; NUM_FEATURES],
    b: [f64; NUM_FEATURES],
) -> Result<[f64; NUM_FEATURES], String> {
    let trace: f64 = (0..NUM_FEATURES).map(|i| g[i][i]).sum();
    if trace <= 0.0 {
        return Err("all-zero system (no live rows?)".into());
    }
    let lam = RIDGE_REL * trace / NUM_FEATURES as f64;
    for i in 0..NUM_FEATURES {
        g[i][i] += lam;
    }
    // Cholesky; on failure escalate the ridge a few times (handles
    // rank-deficient training grids the same way a pivoted solve would,
    // while staying dependency-free).
    let mut lam_boost = lam.max(1e-12);
    for _ in 0..8 {
        let mut l = g;
        if cholesky(&mut l) {
            return Ok(cholesky_solve(&l, &b));
        }
        for i in 0..NUM_FEATURES {
            g[i][i] += lam_boost;
        }
        lam_boost *= 100.0;
    }
    Err("Gram matrix not positive definite even with ridge".into())
}

// -------------------------------------------------------- generic degree

/// Expand one row into a degree-`d` per-parameter polynomial basis:
/// `[1, p1, .., p1^d, p2, .., p2^d]` (the paper's Eqn. 2 generalized —
/// its choice of d = 3 is ablated in `rust/benches/ablation.rs`).
pub fn expand_row_degree(params: &[f64; 2], degree: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(1 + 2 * degree);
    out.push(1.0);
    for &p in params {
        let x = p / super::features::PARAM_SCALE;
        let mut pow = 1.0;
        for _ in 0..degree {
            pow *= x;
            out.push(pow);
        }
    }
    out
}

/// Evaluate a degree-`d` model fitted by [`fit_poly`].
pub fn evaluate_poly(coeffs: &[f64], params: &[f64; 2], degree: usize) -> f64 {
    expand_row_degree(params, degree)
        .iter()
        .zip(coeffs)
        .map(|(a, b)| a * b)
        .sum()
}

/// Weighted least squares for an arbitrary per-parameter degree
/// (dynamic-size Cholesky; the fixed-size path above stays allocation-free
/// for the production degree).
pub fn fit_poly(
    params: &[[f64; 2]],
    times: &[f64],
    weights: &[f64],
    degree: usize,
) -> Result<Vec<f64>, String> {
    assert!(degree >= 1 && degree <= 8, "degree out of supported range");
    let f = 1 + 2 * degree;
    let mut g = vec![vec![0.0; f]; f];
    let mut b = vec![0.0; f];
    for ((p, &w), &t) in params.iter().zip(weights).zip(times) {
        let row = expand_row_degree(p, degree);
        for i in 0..f {
            let wxi = w * row[i];
            b[i] += wxi * t;
            for j in i..f {
                g[i][j] += wxi * row[j];
            }
        }
    }
    for i in 0..f {
        for j in 0..i {
            g[i][j] = g[j][i];
        }
    }
    let trace: f64 = (0..f).map(|i| g[i][i]).sum();
    if trace <= 0.0 {
        return Err("all-zero system".into());
    }
    let mut lam = RIDGE_REL * trace / f as f64;
    for _ in 0..10 {
        for i in 0..f {
            g[i][i] += lam;
        }
        // Dynamic Cholesky.
        let mut l = g.clone();
        let mut ok = true;
        'outer: for i in 0..f {
            for j in 0..=i {
                let mut s = l[i][j];
                for k in 0..j {
                    s -= l[i][k] * l[j][k];
                }
                if i == j {
                    if s <= 0.0 {
                        ok = false;
                        break 'outer;
                    }
                    l[i][j] = s.sqrt();
                } else {
                    l[i][j] = s / l[j][j];
                }
            }
        }
        if ok {
            let mut y = vec![0.0; f];
            for i in 0..f {
                let mut s = b[i];
                for k in 0..i {
                    s -= l[i][k] * y[k];
                }
                y[i] = s / l[i][i];
            }
            let mut x = vec![0.0; f];
            for i in (0..f).rev() {
                let mut s = y[i];
                for k in i + 1..f {
                    s -= l[k][i] * x[k];
                }
                x[i] = s / l[i][i];
            }
            return Ok(x);
        }
        lam = (lam * 100.0).max(1e-10);
    }
    Err("not positive definite even with ridge".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::features::evaluate;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn surface(p: &[f64; 2]) -> f64 {
        let x = p[0] / 40.0;
        let y = p[1] / 40.0;
        200.0 - 150.0 * x + 180.0 * x * x - 60.0 * x * x * x + 40.0 * y + 25.0 * y * y
    }

    fn grid(rng: &mut Rng, n: usize) -> Vec<[f64; 2]> {
        (0..n)
            .map(|_| {
                [
                    rng.range_u64(5, 41) as f64,
                    rng.range_u64(5, 41) as f64,
                ]
            })
            .collect()
    }

    #[test]
    fn recovers_in_family_surface() {
        let mut rng = Rng::new(1);
        let params = grid(&mut rng, 30);
        let times: Vec<f64> = params.iter().map(surface).collect();
        let w = vec![1.0; 30];
        let coeffs = fit(&params, &times, &w).unwrap();
        for (p, &t) in params.iter().zip(&times) {
            let pred = evaluate(&coeffs, p);
            assert!((pred - t).abs() / t < 1e-6, "pred {pred} vs {t}");
        }
    }

    #[test]
    fn zero_weight_rows_ignored() {
        let mut rng = Rng::new(2);
        let mut params = grid(&mut rng, 20);
        let mut times: Vec<f64> = params.iter().map(surface).collect();
        let mut w = vec![1.0; 20];
        // Append garbage rows with zero weight.
        params.push([1e6, -7.0]);
        times.push(1e12);
        w.push(0.0);
        let with_garbage = fit(&params, &times, &w).unwrap();
        let clean = fit(&params[..20], &times[..20], &w[..20]).unwrap();
        for i in 0..NUM_FEATURES {
            assert!((with_garbage[i] - clean[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn degenerate_grid_survives_via_ridge() {
        // Single mapper count -> columns 1..3 collinear with intercept.
        let params: Vec<[f64; 2]> =
            (5..25).map(|r| [20.0, r as f64]).collect();
        let times: Vec<f64> = params.iter().map(surface).collect();
        let w = vec![1.0; params.len()];
        let coeffs = fit(&params, &times, &w).unwrap();
        assert!(coeffs.iter().all(|c| c.is_finite()));
        // In-sample predictions still good.
        for (p, &t) in params.iter().zip(&times) {
            assert!((evaluate(&coeffs, p) - t).abs() / t < 0.02);
        }
    }

    #[test]
    fn all_zero_weights_is_error() {
        let params = vec![[10.0, 10.0]];
        assert!(fit(&params, &[100.0], &[0.0]).is_err());
    }

    #[test]
    fn prop_weighted_reps_equal_mean() {
        // k identical-weight repetitions == one mean row with weight k
        // (the paper's five-run averaging as weights).
        forall("weighted reps", 20, |rng| {
            let params = grid(rng, 12);
            let reps = 5usize;
            let mut all_p = Vec::new();
            let mut all_t = Vec::new();
            let mut means = Vec::new();
            for p in &params {
                let base = surface(p);
                let ts: Vec<f64> =
                    (0..reps).map(|_| base * rng.lognormal(0.05)).collect();
                means.push(ts.iter().sum::<f64>() / reps as f64);
                for &t in &ts {
                    all_p.push(*p);
                    all_t.push(t);
                }
            }
            let a = fit(&all_p, &all_t, &vec![1.0; all_t.len()]).unwrap();
            let b = fit(&params, &means, &vec![reps as f64; params.len()]).unwrap();
            for i in 0..NUM_FEATURES {
                let scale = a[i].abs().max(1.0);
                assert!((a[i] - b[i]).abs() / scale < 1e-7, "coeff {i}");
            }
        });
    }

    #[test]
    fn degree3_poly_matches_fixed_path() {
        let mut rng = Rng::new(3);
        let params = grid(&mut rng, 30);
        let times: Vec<f64> = params
            .iter()
            .map(|p| surface(p) * rng.lognormal(0.03))
            .collect();
        let w = vec![1.0; 30];
        let fixed = fit(&params, &times, &w).unwrap();
        let dynamic = fit_poly(&params, &times, &w, 3).unwrap();
        // Same math, different feature ORDER: fixed is [1,p1,p1^2,p1^3,
        // p2,p2^2,p2^3]; dynamic degree-3 matches exactly.
        for i in 0..NUM_FEATURES {
            assert!((fixed[i] - dynamic[i]).abs() < 1e-9, "coeff {i}");
        }
    }

    #[test]
    fn higher_degree_fits_at_least_as_well() {
        let mut rng = Rng::new(4);
        let params = grid(&mut rng, 40);
        let times: Vec<f64> = params
            .iter()
            .map(|p| surface(p) * rng.lognormal(0.05))
            .collect();
        let w = vec![1.0; 40];
        let mut prev_ss = f64::INFINITY;
        for d in 1..=4 {
            let c = fit_poly(&params, &times, &w, d).unwrap();
            let ss: f64 = params
                .iter()
                .zip(&times)
                .map(|(p, &t)| (evaluate_poly(&c, p, d) - t).powi(2))
                .sum();
            assert!(ss <= prev_ss * (1.0 + 1e-9), "degree {d}: {ss} > {prev_ss}");
            prev_ss = ss;
        }
    }

    #[test]
    fn degree1_is_a_plane() {
        let params: Vec<[f64; 2]> =
            (0..20).map(|i| [5.0 + i as f64, 45.0 - i as f64]).collect();
        let times: Vec<f64> =
            params.iter().map(|p| 10.0 + 2.0 * p[0] + 3.0 * p[1]).collect();
        let c = fit_poly(&params, &times, &vec![1.0; 20], 1).unwrap();
        assert_eq!(c.len(), 3);
        for (p, &t) in params.iter().zip(&times) {
            assert!((evaluate_poly(&c, p, 1) - t).abs() < 1e-6);
        }
    }

    #[test]
    fn prop_fit_residual_not_worse_than_mean_predictor() {
        forall("fit beats mean", 15, |rng| {
            let params = grid(rng, 25);
            let times: Vec<f64> = params
                .iter()
                .map(|p| surface(p) * rng.lognormal(0.1))
                .collect();
            let w = vec![1.0; 25];
            let coeffs = fit(&params, &times, &w).unwrap();
            let mean = times.iter().sum::<f64>() / 25.0;
            let ss_fit: f64 = params
                .iter()
                .zip(&times)
                .map(|(p, &t)| (evaluate(&coeffs, p) - t).powi(2))
                .sum();
            let ss_mean: f64 = times.iter().map(|&t| (t - mean).powi(2)).sum();
            assert!(ss_fit <= ss_mean * (1.0 + 1e-9));
        });
    }
}
