//! The regression model object: fit/predict behind a backend trait.
//!
//! Two interchangeable backends implement the paper's Eqn. 6:
//!
//! * [`RustSolverBackend`] — pure-Rust Cholesky ([`super::solver`]), used
//!   as baseline and cross-check;
//! * [`crate::runtime::XlaBackend`] — the production path executing the
//!   AOT-compiled JAX+Pallas artifacts via PJRT.
//!
//! Both must agree to ~1e-9 relative (asserted in `rust/tests/`).

use crate::profiler::Dataset;
use crate::util::json::{parse, Json};

use super::features::{evaluate, expand_row, NUM_FEATURES};
use super::solver;

/// A fitting backend: raw (M, R) rows + times + weights -> coefficients.
pub trait FitBackend {
    /// Solve the weighted least-squares fit (paper Eqn. 6).
    fn fit(
        &mut self,
        params: &[[f64; 2]],
        times: &[f64],
        weights: &[f64],
    ) -> Result<[f64; NUM_FEATURES], String>;

    /// Batched prediction.  The default evaluates on the CPU; the XLA
    /// backend overrides this to run the predict artifact.
    fn predict(
        &mut self,
        coeffs: &[f64; NUM_FEATURES],
        params: &[[f64; 2]],
    ) -> Result<Vec<f64>, String> {
        Ok(params.iter().map(|p| evaluate(coeffs, p)).collect())
    }

    /// Short backend name for reports ("xla-pjrt", "rust-cholesky").
    fn name(&self) -> &'static str;
}

/// Pure-Rust baseline backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct RustSolverBackend;

impl FitBackend for RustSolverBackend {
    fn fit(
        &mut self,
        params: &[[f64; 2]],
        times: &[f64],
        weights: &[f64],
    ) -> Result<[f64; NUM_FEATURES], String> {
        solver::fit(params, times, weights)
    }

    fn name(&self) -> &'static str {
        "rust-cholesky"
    }
}

/// Incremental normal-equations accumulator for the paper's Eqn. 6 fit.
///
/// Folding one sample is a rank-1 update of the Gram system — O(p²) in
/// the feature count — so a refit after new profiling data costs
/// O(rows · p²) *without re-reading any prior sample*: callers retain
/// only this accumulator (and whatever per-row bookkeeping they need),
/// not the dataset.  This is what lets the online trainer
/// ([`crate::coordinator::trainer`]) keep models fresh as the profile
/// store grows, per the companion CPU-prediction work (arXiv:1203.4054).
///
/// **Exactness contract:** adding rows one at a time performs the same
/// floating-point operations, in the same order, as the batch assembly
/// in [`solver::gram_system`], and [`FitAccumulator::solve`] runs the
/// same ridge policy as [`solver::fit`] — so an incremental fit is
/// *bit-identical* to a from-scratch fit over the same rows in the same
/// order, not an approximation.
///
/// ```
/// use mrtuner::model::regression::FitAccumulator;
///
/// let mut acc = FitAccumulator::new();
/// for m in [5.0, 10.0, 20.0, 40.0] {
///     for r in [5.0, 10.0, 20.0, 40.0] {
///         // A plane is inside the cubic family, so the fit recovers it.
///         acc.add_row(&[m, r], 100.0 + 2.0 * m + 3.0 * r, 1.0);
///     }
/// }
/// assert_eq!(acc.rows(), 16);
/// let coeffs = acc.solve().unwrap();
/// assert!(coeffs.iter().all(|c| c.is_finite()));
/// ```
#[derive(Clone, Debug)]
pub struct FitAccumulator {
    /// Upper triangle of G = XᵀWX (mirrored at solve time).
    g: [[f64; NUM_FEATURES]; NUM_FEATURES],
    /// b = Xᵀ(w∘t).
    b: [f64; NUM_FEATURES],
    rows: usize,
}

impl Default for FitAccumulator {
    fn default() -> Self {
        FitAccumulator::new()
    }
}

impl FitAccumulator {
    /// Empty accumulator (fitting it is an error until a row is added).
    pub fn new() -> FitAccumulator {
        FitAccumulator {
            g: [[0.0; NUM_FEATURES]; NUM_FEATURES],
            b: [0.0; NUM_FEATURES],
            rows: 0,
        }
    }

    /// Fold one observation — a raw `(M, R)` row, its observed time and
    /// its weight — into the system.  O(p²), independent of how many
    /// rows came before.
    pub fn add_row(&mut self, params: &[f64; 2], time_s: f64, weight: f64) {
        let row = expand_row(params);
        for i in 0..NUM_FEATURES {
            let wxi = weight * row[i];
            self.b[i] += wxi * time_s;
            for j in i..NUM_FEATURES {
                self.g[i][j] += wxi * row[j];
            }
        }
        self.rows += 1;
    }

    /// Rows folded in so far.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Fold another accumulator's system into this one (Gram systems are
    /// additive, so shards built independently can be combined).
    pub fn merge(&mut self, other: &FitAccumulator) {
        for i in 0..NUM_FEATURES {
            self.b[i] += other.b[i];
            for j in i..NUM_FEATURES {
                self.g[i][j] += other.g[i][j];
            }
        }
        self.rows += other.rows;
    }

    /// Solve the accumulated system with the production ridge policy —
    /// the same code path as [`solver::fit`], so the coefficients match
    /// a batch fit of the same rows bit-for-bit.
    pub fn solve(&self) -> Result<[f64; NUM_FEATURES], String> {
        if self.rows == 0 {
            return Err("empty accumulator".into());
        }
        // Mirror the upper triangle exactly as `gram_system` does before
        // handing the full matrix to the shared solver.
        let mut g = self.g;
        for i in 0..NUM_FEATURES {
            for j in 0..i {
                g[i][j] = g[j][i];
            }
        }
        solver::solve_gram(g, self.b)
    }
}

/// A fitted per-application model (the paper's "individual model" that the
/// prediction phase uploads, Fig. 2b).
#[derive(Clone, Debug, PartialEq)]
pub struct RegressionModel {
    /// Application this model was fitted for (models don't transfer).
    pub app_name: String,
    /// Fitted coefficients in [`crate::model::features`] order.
    pub coeffs: [f64; NUM_FEATURES],
    /// Rows used for the fit (diagnostics).
    pub trained_on: usize,
}

impl RegressionModel {
    /// Fit a model from a profiled dataset (unit weights — the dataset
    /// rows are already per-experiment means per Fig. 2a).
    pub fn fit_dataset(
        backend: &mut dyn FitBackend,
        ds: &Dataset,
    ) -> Result<RegressionModel, String> {
        if ds.is_empty() {
            return Err("empty dataset".into());
        }
        let weights = vec![1.0; ds.len()];
        let coeffs = backend.fit(&ds.params, &ds.times, &weights)?;
        Ok(RegressionModel {
            app_name: ds.app_name.clone(),
            coeffs,
            trained_on: ds.len(),
        })
    }

    /// Predict a single setting (Eqn. 5).
    pub fn predict_one(&self, num_mappers: u32, num_reducers: u32) -> f64 {
        evaluate(&self.coeffs, &[num_mappers as f64, num_reducers as f64])
    }

    /// Predict a batch of raw parameter rows.
    pub fn predict(&self, params: &[[f64; 2]]) -> Vec<f64> {
        params.iter().map(|p| evaluate(&self.coeffs, p)).collect()
    }

    /// Serialize for persistence / the model registry.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", Json::Str(self.app_name.clone())),
            ("coeffs", Json::from_f64_slice(&self.coeffs)),
            ("trained_on", Json::Num(self.trained_on as f64)),
        ])
    }

    /// Rebuild from [`RegressionModel::to_json`] output.
    pub fn from_json(v: &Json) -> Result<RegressionModel, String> {
        let app_name =
            v.req("app")?.as_str().ok_or("app must be str")?.to_string();
        let cv = v.req("coeffs")?.to_f64_vec()?;
        if cv.len() != NUM_FEATURES {
            return Err(format!("expected {NUM_FEATURES} coeffs, got {}", cv.len()));
        }
        let mut coeffs = [0.0; NUM_FEATURES];
        coeffs.copy_from_slice(&cv);
        let trained_on = v
            .req("trained_on")?
            .as_u64()
            .ok_or("trained_on must be integer")? as usize;
        Ok(RegressionModel { app_name, coeffs, trained_on })
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Load from a file written by [`RegressionModel::save`].
    pub fn load(path: &std::path::Path) -> Result<RegressionModel, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        RegressionModel::from_json(&parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Dataset {
        // Synthetic cubic surface over the paper grid.
        let mut ds = Dataset {
            app_name: "synthetic".into(),
            params: vec![],
            times: vec![],
        };
        for m in (5..=40).step_by(7) {
            for r in (5..=40).step_by(7) {
                let x = m as f64 / 40.0;
                let y = r as f64 / 40.0;
                ds.params.push([m as f64, r as f64]);
                ds.times.push(300.0 - 120.0 * x + 90.0 * x * x + 30.0 * y);
            }
        }
        ds
    }

    #[test]
    fn fit_and_predict_round_trip() {
        let ds = dataset();
        let mut backend = RustSolverBackend;
        let model = RegressionModel::fit_dataset(&mut backend, &ds).unwrap();
        assert_eq!(model.trained_on, ds.len());
        for (p, &t) in ds.params.iter().zip(&ds.times) {
            let pred = model.predict_one(p[0] as u32, p[1] as u32);
            assert!((pred - t).abs() / t < 1e-6);
        }
    }

    #[test]
    fn batch_predict_matches_single() {
        let ds = dataset();
        let model =
            RegressionModel::fit_dataset(&mut RustSolverBackend, &ds).unwrap();
        let batch = model.predict(&ds.params);
        for (i, p) in ds.params.iter().enumerate() {
            assert_eq!(batch[i], model.predict_one(p[0] as u32, p[1] as u32));
        }
    }

    #[test]
    fn empty_dataset_is_error() {
        let ds = Dataset::default();
        assert!(RegressionModel::fit_dataset(&mut RustSolverBackend, &ds).is_err());
    }

    #[test]
    fn json_round_trip() {
        let model =
            RegressionModel::fit_dataset(&mut RustSolverBackend, &dataset()).unwrap();
        let back = RegressionModel::from_json(&model.to_json()).unwrap();
        assert_eq!(model, back);
    }

    #[test]
    fn json_rejects_wrong_coeff_count() {
        let j = parse(r#"{"app":"x","coeffs":[1,2,3],"trained_on":5}"#).unwrap();
        assert!(RegressionModel::from_json(&j).is_err());
    }

    #[test]
    fn accumulator_is_bit_identical_to_batch_fit() {
        let ds = dataset();
        let weights = vec![1.0; ds.len()];
        let batch =
            solver::fit(&ds.params, &ds.times, &weights).unwrap();
        let mut acc = FitAccumulator::new();
        for (p, &t) in ds.params.iter().zip(&ds.times) {
            acc.add_row(p, t, 1.0);
        }
        assert_eq!(acc.rows(), ds.len());
        let incremental = acc.solve().unwrap();
        for i in 0..NUM_FEATURES {
            assert_eq!(
                incremental[i].to_bits(),
                batch[i].to_bits(),
                "coeff {i} must be bit-identical, not approximate"
            );
        }
    }

    #[test]
    fn accumulator_matches_fit_dataset_coefficients() {
        let ds = dataset();
        let model =
            RegressionModel::fit_dataset(&mut RustSolverBackend, &ds).unwrap();
        let mut acc = FitAccumulator::new();
        for (p, &t) in ds.params.iter().zip(&ds.times) {
            acc.add_row(p, t, 1.0);
        }
        let coeffs = acc.solve().unwrap();
        for i in 0..NUM_FEATURES {
            assert_eq!(coeffs[i].to_bits(), model.coeffs[i].to_bits());
        }
    }

    #[test]
    fn merged_shards_solve_like_one_stream() {
        let ds = dataset();
        let mut whole = FitAccumulator::new();
        let mut left = FitAccumulator::new();
        let mut right = FitAccumulator::new();
        let half = ds.len() / 2;
        for (i, (p, &t)) in ds.params.iter().zip(&ds.times).enumerate() {
            whole.add_row(p, t, 1.0);
            if i < half {
                left.add_row(p, t, 1.0);
            } else {
                right.add_row(p, t, 1.0);
            }
        }
        left.merge(&right);
        assert_eq!(left.rows(), whole.rows());
        let a = left.solve().unwrap();
        let b = whole.solve().unwrap();
        for i in 0..NUM_FEATURES {
            // Merging reorders the additions, so equality is numerical
            // (same scale-aware tolerance as the solver's reorder tests)
            // rather than bitwise here.
            let scale = a[i].abs().max(1.0);
            assert!(
                (a[i] - b[i]).abs() / scale < 1e-7,
                "coeff {i}: {} vs {}",
                a[i],
                b[i]
            );
        }
    }

    #[test]
    fn empty_accumulator_is_error() {
        assert!(FitAccumulator::new().solve().is_err());
        assert_eq!(FitAccumulator::default().rows(), 0);
    }
}
