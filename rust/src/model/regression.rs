//! The regression model object: fit/predict behind a backend trait.
//!
//! Two interchangeable backends implement the paper's Eqn. 6:
//!
//! * [`RustSolverBackend`] — pure-Rust Cholesky ([`super::solver`]), used
//!   as baseline and cross-check;
//! * [`crate::runtime::XlaBackend`] — the production path executing the
//!   AOT-compiled JAX+Pallas artifacts via PJRT.
//!
//! Both must agree to ~1e-9 relative (asserted in `rust/tests/`).

use crate::profiler::Dataset;
use crate::util::json::{parse, Json};

use super::features::{evaluate, NUM_FEATURES};
use super::solver;

/// A fitting backend: raw (M, R) rows + times + weights -> coefficients.
pub trait FitBackend {
    /// Solve the weighted least-squares fit (paper Eqn. 6).
    fn fit(
        &mut self,
        params: &[[f64; 2]],
        times: &[f64],
        weights: &[f64],
    ) -> Result<[f64; NUM_FEATURES], String>;

    /// Batched prediction.  The default evaluates on the CPU; the XLA
    /// backend overrides this to run the predict artifact.
    fn predict(
        &mut self,
        coeffs: &[f64; NUM_FEATURES],
        params: &[[f64; 2]],
    ) -> Result<Vec<f64>, String> {
        Ok(params.iter().map(|p| evaluate(coeffs, p)).collect())
    }

    /// Short backend name for reports ("xla-pjrt", "rust-cholesky").
    fn name(&self) -> &'static str;
}

/// Pure-Rust baseline backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct RustSolverBackend;

impl FitBackend for RustSolverBackend {
    fn fit(
        &mut self,
        params: &[[f64; 2]],
        times: &[f64],
        weights: &[f64],
    ) -> Result<[f64; NUM_FEATURES], String> {
        solver::fit(params, times, weights)
    }

    fn name(&self) -> &'static str {
        "rust-cholesky"
    }
}

/// A fitted per-application model (the paper's "individual model" that the
/// prediction phase uploads, Fig. 2b).
#[derive(Clone, Debug, PartialEq)]
pub struct RegressionModel {
    /// Application this model was fitted for (models don't transfer).
    pub app_name: String,
    /// Fitted coefficients in [`crate::model::features`] order.
    pub coeffs: [f64; NUM_FEATURES],
    /// Rows used for the fit (diagnostics).
    pub trained_on: usize,
}

impl RegressionModel {
    /// Fit a model from a profiled dataset (unit weights — the dataset
    /// rows are already per-experiment means per Fig. 2a).
    pub fn fit_dataset(
        backend: &mut dyn FitBackend,
        ds: &Dataset,
    ) -> Result<RegressionModel, String> {
        if ds.is_empty() {
            return Err("empty dataset".into());
        }
        let weights = vec![1.0; ds.len()];
        let coeffs = backend.fit(&ds.params, &ds.times, &weights)?;
        Ok(RegressionModel {
            app_name: ds.app_name.clone(),
            coeffs,
            trained_on: ds.len(),
        })
    }

    /// Predict a single setting (Eqn. 5).
    pub fn predict_one(&self, num_mappers: u32, num_reducers: u32) -> f64 {
        evaluate(&self.coeffs, &[num_mappers as f64, num_reducers as f64])
    }

    /// Predict a batch of raw parameter rows.
    pub fn predict(&self, params: &[[f64; 2]]) -> Vec<f64> {
        params.iter().map(|p| evaluate(&self.coeffs, p)).collect()
    }

    /// Serialize for persistence / the model registry.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", Json::Str(self.app_name.clone())),
            ("coeffs", Json::from_f64_slice(&self.coeffs)),
            ("trained_on", Json::Num(self.trained_on as f64)),
        ])
    }

    /// Rebuild from [`RegressionModel::to_json`] output.
    pub fn from_json(v: &Json) -> Result<RegressionModel, String> {
        let app_name =
            v.req("app")?.as_str().ok_or("app must be str")?.to_string();
        let cv = v.req("coeffs")?.to_f64_vec()?;
        if cv.len() != NUM_FEATURES {
            return Err(format!("expected {NUM_FEATURES} coeffs, got {}", cv.len()));
        }
        let mut coeffs = [0.0; NUM_FEATURES];
        coeffs.copy_from_slice(&cv);
        let trained_on = v
            .req("trained_on")?
            .as_u64()
            .ok_or("trained_on must be integer")? as usize;
        Ok(RegressionModel { app_name, coeffs, trained_on })
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Load from a file written by [`RegressionModel::save`].
    pub fn load(path: &std::path::Path) -> Result<RegressionModel, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        RegressionModel::from_json(&parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Dataset {
        // Synthetic cubic surface over the paper grid.
        let mut ds = Dataset {
            app_name: "synthetic".into(),
            params: vec![],
            times: vec![],
        };
        for m in (5..=40).step_by(7) {
            for r in (5..=40).step_by(7) {
                let x = m as f64 / 40.0;
                let y = r as f64 / 40.0;
                ds.params.push([m as f64, r as f64]);
                ds.times.push(300.0 - 120.0 * x + 90.0 * x * x + 30.0 * y);
            }
        }
        ds
    }

    #[test]
    fn fit_and_predict_round_trip() {
        let ds = dataset();
        let mut backend = RustSolverBackend;
        let model = RegressionModel::fit_dataset(&mut backend, &ds).unwrap();
        assert_eq!(model.trained_on, ds.len());
        for (p, &t) in ds.params.iter().zip(&ds.times) {
            let pred = model.predict_one(p[0] as u32, p[1] as u32);
            assert!((pred - t).abs() / t < 1e-6);
        }
    }

    #[test]
    fn batch_predict_matches_single() {
        let ds = dataset();
        let model =
            RegressionModel::fit_dataset(&mut RustSolverBackend, &ds).unwrap();
        let batch = model.predict(&ds.params);
        for (i, p) in ds.params.iter().enumerate() {
            assert_eq!(batch[i], model.predict_one(p[0] as u32, p[1] as u32));
        }
    }

    #[test]
    fn empty_dataset_is_error() {
        let ds = Dataset::default();
        assert!(RegressionModel::fit_dataset(&mut RustSolverBackend, &ds).is_err());
    }

    #[test]
    fn json_round_trip() {
        let model =
            RegressionModel::fit_dataset(&mut RustSolverBackend, &dataset()).unwrap();
        let back = RegressionModel::from_json(&model.to_json()).unwrap();
        assert_eq!(model, back);
    }

    #[test]
    fn json_rejects_wrong_coeff_count() {
        let j = parse(r#"{"app":"x","coeffs":[1,2,3],"trained_on":5}"#).unwrap();
        assert!(RegressionModel::from_json(&j).is_err());
    }
}
