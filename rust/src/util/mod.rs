//! Self-contained utility layer.
//!
//! The offline vendor set ships only the `xla` crate closure, so everything
//! a framework normally pulls from crates.io — PRNG, statistics, JSON,
//! CLI parsing, property testing — is implemented here from scratch.

pub mod benchkit;
pub mod bytes;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
