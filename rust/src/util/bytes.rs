//! Byte-size constants, formatting, and fixed-width hex codecs.

/// One kibibyte (2^10 bytes).
pub const KB: u64 = 1 << 10;
/// One mebibyte (2^20 bytes).
pub const MB: u64 = 1 << 20;
/// One gibibyte (2^30 bytes).
pub const GB: u64 = 1 << 30;

/// Render a `u64` as fixed-width (16-digit) lowercase hex.
///
/// The profile store persists `u64` seeds, fingerprints and `f64` bit
/// patterns this way because JSON numbers are f64 and silently lose
/// integer precision above 2^53 — a hex string round-trips every bit.
pub fn hex_u64(v: u64) -> String {
    format!("{v:016x}")
}

/// Parse a `u64` from the hex form written by [`hex_u64`] (any length up
/// to 16 digits, case-insensitive).
pub fn parse_hex_u64(s: &str) -> Result<u64, String> {
    if s.is_empty() || s.len() > 16 {
        return Err(format!("bad hex u64 '{s}'"));
    }
    u64::from_str_radix(s, 16).map_err(|_| format!("bad hex u64 '{s}'"))
}

/// Render a byte count in the most natural unit ("8.0 GB", "640.0 MB").
pub fn fmt_bytes(b: u64) -> String {
    let bf = b as f64;
    if b >= GB {
        format!("{:.1} GB", bf / GB as f64)
    } else if b >= MB {
        format!("{:.1} MB", bf / MB as f64)
    } else if b >= KB {
        format!("{:.1} KB", bf / KB as f64)
    } else {
        format!("{b} B")
    }
}

/// Render seconds as "1h02m03s" / "4m05s" / "12.3s".
pub fn fmt_secs(s: f64) -> String {
    if s >= 3600.0 {
        let h = (s / 3600.0).floor();
        let m = ((s - h * 3600.0) / 60.0).floor();
        let sec = s - h * 3600.0 - m * 60.0;
        format!("{h:.0}h{m:02.0}m{sec:02.0}s")
    } else if s >= 60.0 {
        let m = (s / 60.0).floor();
        format!("{m:.0}m{:02.0}s", s - m * 60.0)
    } else {
        format!("{s:.1}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_bytes() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * KB), "2.0 KB");
        assert_eq!(fmt_bytes(8 * GB), "8.0 GB");
        assert_eq!(fmt_bytes(1536 * MB), "1.5 GB");
    }

    #[test]
    fn hex_u64_round_trips() {
        for v in [0u64, 1, 0x53, u64::MAX, 0x9E37_79B9_7F4A_7C15] {
            assert_eq!(parse_hex_u64(&hex_u64(v)).unwrap(), v);
        }
        assert_eq!(hex_u64(0x53), "0000000000000053");
        assert!(parse_hex_u64("").is_err());
        assert!(parse_hex_u64("xyz").is_err());
        assert!(parse_hex_u64("00000000000000000").is_err(), "17 digits");
    }

    #[test]
    fn formats_secs() {
        assert_eq!(fmt_secs(12.34), "12.3s");
        assert_eq!(fmt_secs(65.0), "1m05s");
        assert_eq!(fmt_secs(3723.0), "1h02m03s");
    }
}
