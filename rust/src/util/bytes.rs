//! Byte-size constants and formatting.

pub const KB: u64 = 1 << 10;
pub const MB: u64 = 1 << 20;
pub const GB: u64 = 1 << 30;

/// Render a byte count in the most natural unit ("8.0 GB", "640.0 MB").
pub fn fmt_bytes(b: u64) -> String {
    let bf = b as f64;
    if b >= GB {
        format!("{:.1} GB", bf / GB as f64)
    } else if b >= MB {
        format!("{:.1} MB", bf / MB as f64)
    } else if b >= KB {
        format!("{:.1} KB", bf / KB as f64)
    } else {
        format!("{b} B")
    }
}

/// Render seconds as "1h02m03s" / "4m05s" / "12.3s".
pub fn fmt_secs(s: f64) -> String {
    if s >= 3600.0 {
        let h = (s / 3600.0).floor();
        let m = ((s - h * 3600.0) / 60.0).floor();
        let sec = s - h * 3600.0 - m * 60.0;
        format!("{h:.0}h{m:02.0}m{sec:02.0}s")
    } else if s >= 60.0 {
        let m = (s / 60.0).floor();
        format!("{m:.0}m{:02.0}s", s - m * 60.0)
    } else {
        format!("{s:.1}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_bytes() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * KB), "2.0 KB");
        assert_eq!(fmt_bytes(8 * GB), "8.0 GB");
        assert_eq!(fmt_bytes(1536 * MB), "1.5 GB");
    }

    #[test]
    fn formats_secs() {
        assert_eq!(fmt_secs(12.34), "12.3s");
        assert_eq!(fmt_secs(65.0), "1m05s");
        assert_eq!(fmt_secs(3723.0), "1h02m03s");
    }
}
