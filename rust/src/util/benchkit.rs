//! Minimal benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets are built with `harness = false` and drive this
//! kit: warmup + timed iterations, robust summary statistics, and a
//! uniform output format the perf pass (EXPERIMENTS.md §Perf) records.

use std::time::Instant;

use super::stats;

/// Timing summary for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Case label.
    pub name: String,
    /// Timed iterations.
    pub iters: u32,
    /// Mean iteration time.
    pub mean_s: f64,
    /// Iteration-time standard deviation.
    pub stddev_s: f64,
    /// Fastest iteration.
    pub min_s: f64,
    /// Median iteration time.
    pub p50_s: f64,
    /// 99th-percentile iteration time.
    pub p99_s: f64,
}

impl BenchStats {
    /// Units processed per second at the mean iteration time.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }
}

fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` for `warmup` unmeasured plus `iters` measured iterations and
/// print one summary line.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let st = BenchStats {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        stddev_s: stats::stddev(&samples),
        min_s: stats::min(&samples),
        p50_s: stats::percentile(&samples, 50.0),
        p99_s: stats::percentile(&samples, 99.0),
    };
    println!(
        "bench {:<44} {:>10}/iter  (p50 {:>10}, p99 {:>10}, min {:>10}, n={})",
        st.name,
        fmt_t(st.mean_s),
        fmt_t(st.p50_s),
        fmt_t(st.p99_s),
        fmt_t(st.min_s),
        st.iters
    );
    st
}

/// Print a section header (keeps bench output greppable).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a labeled scalar result (for report-style benches that check
/// reproduction quality rather than time).
pub fn report(label: &str, value: impl std::fmt::Display) {
    println!("result {label:<50} {value}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_requested_iterations() {
        let mut n = 0u32;
        let st = bench("noop", 2, 5, || n += 1);
        assert_eq!(n, 7); // warmup + iters
        assert_eq!(st.iters, 5);
        assert!(st.mean_s >= 0.0);
        assert!(st.min_s <= st.p50_s);
        assert!(st.p50_s <= st.p99_s + 1e-12);
    }

    #[test]
    fn throughput_scales() {
        let st = bench("sleepless", 0, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(st.throughput(1000.0) > 0.0);
    }
}
