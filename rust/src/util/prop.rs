//! Minimal property-testing harness (no proptest available offline).
//!
//! `forall(name, cases, |rng| ...)` runs a closure against `cases`
//! deterministically derived RNG streams; a failing case panics with the
//! seed so it can be replayed with `replay(seed, f)`.  No shrinking — cases
//! are kept small and structured instead.

use super::rng::Rng;

/// Base seed; change via MRTUNER_PROP_SEED to explore new corners in CI.
fn base_seed() -> u64 {
    std::env::var("MRTUNER_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x6d72_7475_6e65_7221)
}

/// Run `f` for `cases` independent seeds.  `f` gets a fresh RNG per case and
/// should panic (assert) on property violation.
pub fn forall<F: FnMut(&mut Rng)>(name: &str, cases: u32, mut f: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed on case {case} (replay seed {seed:#x})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnOnce(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        forall("counting", 17, |_| n += 1);
        assert_eq!(n, 17);
    }

    #[test]
    fn cases_get_distinct_streams() {
        let mut seen = Vec::new();
        forall("distinct", 8, |rng| seen.push(rng.next_u64()));
        let mut sorted = seen.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), seen.len());
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        forall("fails", 4, |rng| {
            assert!(rng.f64() < 2.0); // always true...
            panic!("boom"); // ...then explicit failure
        });
    }
}
