//! Minimal JSON reader/writer.
//!
//! Used for the artifact manifest, persisted profiling datasets, saved
//! models and the coordinator's line-delimited TCP protocol.  Supports the
//! full JSON grammar except exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Objects use a BTreeMap so serialization is deterministic
/// (stable diffs for datasets checked into EXPERIMENTS runs).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always an f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object; BTreeMap keeps key order deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup; `None` for non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a `u64`, if it is a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Fetch a required field, with a path-bearing error.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }

    /// Fetch a required string field.
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| format!("field '{key}' must be a string"))
    }

    /// Fetch a required non-negative integer field (exact in f64).
    pub fn req_u64(&self, key: &str) -> Result<u64, String> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| format!("field '{key}' must be a non-negative integer"))
    }

    /// Fetch a required `u32` field.
    pub fn req_u32(&self, key: &str) -> Result<u32, String> {
        let v = self.req_u64(key)?;
        u32::try_from(v).map_err(|_| format!("field '{key}' out of u32 range"))
    }

    /// Array of numbers from a slice.
    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Flatten an all-number array back into a `Vec<f64>`.
    pub fn to_f64_vec(&self) -> Result<Vec<f64>, String> {
        self.as_arr()
            .ok_or_else(|| "expected array".to_string())?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| "expected number".to_string()))
            .collect()
    }
}

// ---------------------------------------------------------------- writing

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{n}")
                    }
                } else {
                    // JSON has no Inf/NaN; null is the conventional fallback.
                    write!(f, "null")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// ---------------------------------------------------------------- parsing

/// Parse one complete JSON document (trailing garbage is an error).
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = self
                            .b
                            .get(self.i..self.i + 4)
                            .ok_or("bad \\u escape")?;
                        self.i += 4;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                            16,
                        )
                        .map_err(|_| "bad \\u hex")?;
                        // Surrogate pairs: decode if a high surrogate.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err("lone high surrogate".into());
                            }
                            let hex2 = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("bad \\u escape")?;
                            self.i += 4;
                            let lo = u32::from_str_radix(
                                std::str::from_utf8(hex2).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u hex")?;
                            0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            code
                        };
                        out.push(char::from_u32(ch).ok_or("bad codepoint")?);
                    }
                    _ => return Err("bad escape".into()),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let len = utf8_len(c);
                    let start = self.i - 1;
                    self.i = start + len;
                    let bytes =
                        self.b.get(start..start + len).ok_or("truncated utf8")?;
                    out.push_str(
                        std::str::from_utf8(bytes).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.25", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn round_trips_deep() {
        let src = r#"{"m":{"arr":[1,2.5,-3e2,true,false,null,"s"],"o":{}}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::Str("a\"b\\c\n\u{1}".into());
        let s = v.to_string();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escapes_and_raw() {
        let v = parse(r#""é€ 😀 é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é€ 😀 é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_render_without_exponent() {
        assert_eq!(Json::Num(64.0).to_string(), "64");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn required_field_helpers() {
        let v = parse(r#"{"s":"x","n":7,"neg":-1,"f":1.5}"#).unwrap();
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_u64("n").unwrap(), 7);
        assert_eq!(v.req_u32("n").unwrap(), 7);
        assert!(v.req_str("n").is_err());
        assert!(v.req_u64("neg").is_err());
        assert!(v.req_u64("f").is_err());
        assert!(v.req_u64("missing").is_err());
        assert!(parse(&format!("{{\"big\":{}}}", (1u64 << 40)))
            .unwrap()
            .req_u32("big")
            .is_err());
    }

    #[test]
    fn f64_vec_helpers() {
        let v = Json::from_f64_slice(&[1.0, 2.5]);
        assert_eq!(v.to_f64_vec().unwrap(), vec![1.0, 2.5]);
        assert!(Json::Null.to_f64_vec().is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let a = parse(r#"{"z":1,"a":2}"#).unwrap().to_string();
        let b = parse(r#"{"a":2,"z":1}"#).unwrap().to_string();
        assert_eq!(a, b);
    }
}
