//! Tiny command-line parser:
//! `binary SUBCOMMAND [ACTION...] --flag value --switch`.
//!
//! Hand-rolled because no argument-parsing crate is available offline.
//! Unknown flags are an error (catches typos in experiment scripts), and
//! so are positional arguments the subcommand never consumed.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional actions, `--flag value`
/// pairs and bare `--switch`es, with consumption tracking so typos fail.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First bare token, if any (`mrtuner <SUBCOMMAND> ...`).
    pub subcommand: Option<String>,
    positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
    consumed_pos: std::cell::RefCell<usize>,
}

impl Args {
    /// Parse raw argv (without the binary name).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        if i < argv.len() && !argv[i].starts_with("--") {
            out.subcommand = Some(argv[i].clone());
            i += 1;
        }
        while i < argv.len() {
            let a = &argv[i];
            let name = match a.strip_prefix("--") {
                Some(name) => name,
                None => {
                    // Bare token that is not a flag value: a positional
                    // action (`mrtuner store stats --store DIR`).
                    out.positionals.push(a.clone());
                    i += 1;
                    continue;
                }
            };
            if name.is_empty() {
                return Err("empty flag name".into());
            }
            if let Some((k, v)) = name.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                out.flags.insert(name.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                out.switches.push(name.to_string());
            }
            i += 1;
        }
        Ok(out)
    }

    /// The `i`-th positional argument after the subcommand, if present.
    pub fn positional(&self, i: usize) -> Option<String> {
        let mut hw = self.consumed_pos.borrow_mut();
        *hw = (*hw).max(i + 1);
        self.positionals.get(i).cloned()
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    fn mark(&self, name: &str) {
        self.consumed.borrow_mut().push(name.to_string());
    }

    /// `--name value`, if given.
    pub fn str_opt(&self, name: &str) -> Option<String> {
        self.mark(name);
        self.flags.get(name).cloned()
    }

    /// `--name value` with a default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or_else(|| default.to_string())
    }

    /// Integer flag with a default; bad values are an error.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name}: bad integer '{s}'")),
        }
    }

    /// Float flag with a default; bad values are an error.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name}: bad number '{s}'")),
        }
    }

    /// Whether the bare switch `--name` was given.
    pub fn switch(&self, name: &str) -> bool {
        self.mark(name);
        self.switches.iter().any(|s| s == name)
    }

    /// Error on any flag/switch/positional never consumed by the
    /// subcommand.
    pub fn reject_unknown(&self) -> Result<(), String> {
        let seen = self.consumed.borrow();
        let unknown: Vec<&str> = self
            .flags
            .keys()
            .map(|s| s.as_str())
            .chain(self.switches.iter().map(|s| s.as_str()))
            .filter(|n| !seen.iter().any(|s| s == n))
            .collect();
        if !unknown.is_empty() {
            return Err(format!("unknown flag(s): {}", unknown.join(", ")));
        }
        let hw = *self.consumed_pos.borrow();
        if self.positionals.len() > hw {
            return Err(format!(
                "unexpected argument(s): {}",
                self.positionals[hw..].join(", ")
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(&argv(&[
            "profile", "--app", "wordcount", "--reps=5", "--verbose",
        ]))
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("profile"));
        assert_eq!(a.str_opt("app").as_deref(), Some("wordcount"));
        assert_eq!(a.u64_or("reps", 1).unwrap(), 5);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(&["fit"])).unwrap();
        assert_eq!(a.u64_or("seed", 42).unwrap(), 42);
        assert_eq!(a.f64_or("noise", 0.1).unwrap(), 0.1);
        assert_eq!(a.str_or("app", "wordcount"), "wordcount");
    }

    #[test]
    fn rejects_bad_values_and_unknown() {
        let a = Args::parse(&argv(&["x", "--n", "abc"])).unwrap();
        assert!(a.u64_or("n", 0).is_err());
        let b = Args::parse(&argv(&["x", "--typo", "1"])).unwrap();
        assert!(b.reject_unknown().is_err());
    }

    #[test]
    fn negative_number_values() {
        // "--shift -3" would parse -3 as a flag; "=" form handles negatives.
        let a = Args::parse(&argv(&["x", "--shift=-3.5"])).unwrap();
        assert_eq!(a.f64_or("shift", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = Args::parse(&argv(&["store", "stats", "--store", "d"])).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("store"));
        assert_eq!(a.positional(0).as_deref(), Some("stats"));
        assert_eq!(a.positional(1), None);
        assert_eq!(a.str_opt("store").as_deref(), Some("d"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn unconsumed_positionals_rejected() {
        let a = Args::parse(&argv(&["store", "stats", "oops"])).unwrap();
        assert_eq!(a.positional(0).as_deref(), Some("stats"));
        assert!(a.reject_unknown().is_err(), "'oops' never consumed");
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse(&argv(&["--help"])).unwrap();
        assert_eq!(a.subcommand, None);
        assert!(a.switch("help"));
    }
}
