//! Deterministic pseudo-random number generation.
//!
//! The simulator's reproducibility contract is that a `(seed, config)` pair
//! always yields the same execution time, so all stochastic behaviour flows
//! from this module.  Core generator is xoshiro256++ seeded via SplitMix64
//! (the reference initialization recommended by the xoshiro authors).

/// SplitMix64 step — used to expand a single `u64` seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (stable stream splitting).
    ///
    /// Used to give each task / node / data generator its own stream so the
    /// order in which subsystems draw numbers cannot perturb each other.
    pub fn fork(&self, stream: u64) -> Rng {
        // Mix the child stream id through SplitMix so sibling streams with
        // adjacent ids are decorrelated.
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit draw (xoshiro256** step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`.  Panics if `lo >= hi`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        let span = hi - lo;
        // Lemire-style rejection to kill modulo bias.
        let zone = u64::MAX - (u64::MAX - span + 1) % span;
        loop {
            let v = self.next_u64();
            if v <= zone {
                return lo + v % span;
            }
        }
    }

    /// Uniform integer in `[lo, hi)` as `usize`.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with the given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Lognormal multiplier with median 1 and shape `sigma`.
    ///
    /// Used for run-to-run "temporal changes" (paper §IV.A): multiplicative
    /// noise on task durations, heavier-tailed than Gaussian, never negative.
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }
}

/// Zipf(s) sampler over ranks `1..=n` using rejection-inversion
/// (Hörmann & Derflinger), O(1) per sample.  Drives the synthetic text
/// corpus: natural-language word frequencies are famously Zipfian, which is
/// what makes WordCount's combiner/selectivity behaviour realistic.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    c: f64,
}

impl Zipf {
    /// Zipf distribution over `1..=n` with exponent `s` (`s != 1`).
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1 && s > 0.0 && (s - 1.0).abs() > 1e-9, "s != 1, n >= 1");
        let h = |x: f64| (x.powf(1.0 - s) - 1.0) / (1.0 - s);
        Zipf {
            n,
            s,
            h_x1: h(1.5) - 1.0,
            h_n: h(n as f64 + 0.5),
            c: 1.0 - s,
        }
    }

    fn h_inv(&self, x: f64) -> f64 {
        (1.0 + x * self.c).powf(1.0 / self.c)
    }

    fn h(&self, x: f64) -> f64 {
        (x.powf(1.0 - self.s) - 1.0) / (1.0 - self.s)
    }

    /// Sample a rank in `1..=n` (rank 1 is the most frequent).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            if k - x <= self.s_accept(k) || u >= self.h(k + 0.5) - k.powf(-self.s) {
                return k as u64;
            }
        }
    }

    fn s_accept(&self, _k: f64) -> f64 {
        // Conservative acceptance shortcut constant; exactness comes from
        // the second predicate in `sample`.
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_streams_are_independent_and_stable() {
        let root = Rng::new(7);
        let mut c1 = root.fork(0);
        let mut c1b = root.fork(0);
        let mut c2 = root.fork(1);
        assert_eq!(c1.next_u64(), c1b.next_u64());
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds_inclusive_exclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(5, 8);
            assert!((5..8).contains(&v));
            seen_lo |= v == 5;
            seen_hi |= v == 7;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn lognormal_positive_median_one() {
        let mut r = Rng::new(13);
        let mut xs: Vec<f64> = (0..20_001).map(|_| r.lognormal(0.2)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        xs.sort_by(|a, b| a.total_cmp(b));
        let median = xs[10_000];
        assert!((median - 1.0).abs() < 0.02, "median {median}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_rank_one_most_frequent() {
        let mut r = Rng::new(19);
        let z = Zipf::new(1000, 1.1);
        let mut counts = [0u32; 4];
        for _ in 0..50_000 {
            let k = z.sample(&mut r);
            assert!((1..=1000).contains(&k));
            if k <= 4 {
                counts[(k - 1) as usize] += 1;
            }
        }
        assert!(counts[0] > counts[1]);
        assert!(counts[1] > counts[2]);
    }

    #[test]
    fn zipf_small_n() {
        let mut r = Rng::new(23);
        let z = Zipf::new(1, 1.2);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut r), 1);
        }
    }
}
