//! Statistics helpers used by the profiler, metrics and bench harness.

/// Arithmetic mean.  Returns 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (the paper's Table 1 reports population moments of
/// the percentage errors).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Sample (Bessel-corrected) variance.
pub fn sample_variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, `q` in `[0, 100]`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // `total_cmp` is a total order: NaNs (e.g. from a degenerate model
    // fit upstream) sort to the ends instead of panicking the comparator.
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Smallest element (`inf` when empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Largest element (`-inf` when empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Mean absolute percentage error of `pred` against `truth` — the
/// held-out metric the extension sweeps and benches report.
pub fn mean_abs_err_pct(pred: &[f64], truth: &[f64]) -> f64 {
    let errs: Vec<f64> = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| 100.0 * (p - t).abs() / t)
        .collect();
    mean(&errs)
}

/// Coefficient of determination of predictions vs observations.
pub fn r_squared(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    let m = mean(actual);
    let ss_tot: f64 = actual.iter().map(|a| (a - m) * (a - m)).sum();
    let ss_res: f64 = actual
        .iter()
        .zip(predicted)
        .map(|(a, p)| (a - p) * (a - p))
        .sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { f64::NEG_INFINITY };
    }
    1.0 - ss_res / ss_tot
}

/// Streaming mean/variance accumulator (Welford).  Used in the DES hot loop
/// and the bench harness where collecting every sample would allocate.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one observation in (Welford update).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Running population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Running standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold another accumulator in (parallel-merge form).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n;
        self.mean = (self.mean * self.n as f64 + other.mean * other.n as f64) / n;
        self.m2 = m2;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_known_values() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert_eq!(variance(&xs), 4.0);
        assert_eq!(stddev(&xs), 2.0);
        assert!((sample_variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(variance(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        // Unsorted input is handled.
        let ys = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&ys, 50.0), 2.5);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // This used to panic via `partial_cmp(..).unwrap()`.
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        // Positive NaN sorts last under total_cmp: low quantiles stay
        // finite and answer from the real data ...
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        // ... and the top quantile lands on the NaN, honestly.
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn r_squared_perfect_and_mean_predictor() {
        let a = [1.0, 2.0, 3.0];
        assert_eq!(r_squared(&a, &a), 1.0);
        let mean_pred = [2.0, 2.0, 2.0];
        assert_eq!(r_squared(&a, &mean_pred), 0.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
        assert_eq!(o.count(), 8);
    }

    #[test]
    fn online_merge_matches_whole() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - mean(&xs)).abs() < 1e-9);
        assert!((a.variance() - variance(&xs)).abs() < 1e-9);
    }
}
