//! Statistics helpers used by the profiler, metrics and bench harness.

/// Arithmetic mean.  Returns 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (the paper's Table 1 reports population moments of
/// the percentage errors).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Sample (Bessel-corrected) variance.
pub fn sample_variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, `q` in `[0, 100]`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // `total_cmp` is a total order: NaNs (e.g. from a degenerate model
    // fit upstream) sort to the ends instead of panicking the comparator.
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Smaller of two floats under IEEE-754 total order.
///
/// Unlike [`f64::min`], which silently prefers the non-NaN operand, a
/// NaN here is *larger* than every real number — so a NaN fed into a
/// running minimum is ignored deterministically (never "wins" depending
/// on operand order), while [`total_max`] surfaces it honestly.
pub fn total_min(a: f64, b: f64) -> f64 {
    if b.total_cmp(&a) == std::cmp::Ordering::Less {
        b
    } else {
        a
    }
}

/// Larger of two floats under IEEE-754 total order.
///
/// A positive NaN is the largest value in the total order, so a NaN
/// sample propagates into a running maximum instead of being silently
/// dropped the way [`f64::max`] drops it.
pub fn total_max(a: f64, b: f64) -> f64 {
    if b.total_cmp(&a) == std::cmp::Ordering::Greater {
        b
    } else {
        a
    }
}

/// Smallest element (`inf` when empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, total_min)
}

/// Largest element (`-inf` when empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, total_max)
}

/// Mean absolute percentage error of `pred` against `truth` — the
/// held-out metric the extension sweeps and benches report.
pub fn mean_abs_err_pct(pred: &[f64], truth: &[f64]) -> f64 {
    let errs: Vec<f64> = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| 100.0 * (p - t).abs() / t)
        .collect();
    mean(&errs)
}

/// Coefficient of determination of predictions vs observations.
pub fn r_squared(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    let m = mean(actual);
    let ss_tot: f64 = actual.iter().map(|a| (a - m) * (a - m)).sum();
    let ss_res: f64 = actual
        .iter()
        .zip(predicted)
        .map(|(a, p)| (a - p) * (a - p))
        .sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { f64::NEG_INFINITY };
    }
    1.0 - ss_res / ss_tot
}

/// Streaming mean/variance accumulator (Welford).  Used in the DES hot loop
/// and the bench harness where collecting every sample would allocate.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one observation in (Welford update).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = total_min(self.min, x);
        self.max = total_max(self.max, x);
    }

    /// Observations folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Running population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Running standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold another accumulator in (parallel-merge form).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n;
        self.mean = (self.mean * self.n as f64 + other.mean * other.n as f64) / n;
        self.m2 = m2;
        self.n += other.n;
        self.min = total_min(self.min, other.min);
        self.max = total_max(self.max, other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_known_values() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert_eq!(variance(&xs), 4.0);
        assert_eq!(stddev(&xs), 2.0);
        assert!((sample_variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(variance(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        // Unsorted input is handled.
        let ys = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&ys, 50.0), 2.5);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // This used to panic via `partial_cmp(..).unwrap()`.
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        // Positive NaN sorts last under total_cmp: low quantiles stay
        // finite and answer from the real data ...
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        // ... and the top quantile lands on the NaN, honestly.
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn total_order_extrema_are_nan_deterministic() {
        // Operand order never changes the answer (f64::min/max's NaN
        // handling is operand-order dependent; total order is not).
        assert_eq!(total_min(f64::NAN, 2.0), 2.0);
        assert_eq!(total_min(2.0, f64::NAN), 2.0);
        assert!(total_max(f64::NAN, 2.0).is_nan());
        assert!(total_max(2.0, f64::NAN).is_nan());
        // Signed zero is ordered, not collapsed.
        assert_eq!(total_min(0.0, -0.0).to_bits(), (-0.0f64).to_bits());
        assert_eq!(total_max(-0.0, 0.0).to_bits(), 0.0f64.to_bits());
        // Slice forms inherit the same behaviour.
        let xs = [3.0, f64::NAN, 1.0];
        assert_eq!(min(&xs), 1.0);
        assert!(max(&xs).is_nan());
    }

    #[test]
    fn online_stats_extrema_survive_nan() {
        let mut o = OnlineStats::new();
        o.push(5.0);
        o.push(f64::NAN);
        o.push(1.0);
        assert_eq!(o.min(), 1.0, "min ignores the NaN sample");
        assert!(o.max().is_nan(), "max surfaces the NaN sample");
        let mut m = OnlineStats::new();
        m.push(0.5);
        m.merge(&o);
        assert_eq!(m.min(), 0.5);
        assert!(m.max().is_nan());
    }

    #[test]
    fn r_squared_perfect_and_mean_predictor() {
        let a = [1.0, 2.0, 3.0];
        assert_eq!(r_squared(&a, &a), 1.0);
        let mean_pred = [2.0, 2.0, 2.0];
        assert_eq!(r_squared(&a, &mean_pred), 0.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
        assert_eq!(o.count(), 8);
    }

    #[test]
    fn online_merge_matches_whole() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - mean(&xs)).abs() < 1e-9);
        assert!((a.variance() - variance(&xs)).abs() < 1e-9);
    }
}
