//! Two-input reduce-side equi-join — the skew-prone extension app.
//!
//! Models the classic repartition join: both inputs arrive tagged
//! (`L\tkey\tpayload` for the left relation, `R\tkey\tpayload` for the
//! right), mappers re-key every record on the join key with a
//! side-marker prefix, and reducers cross-product the two sides per
//! key.  Hot keys blow up the cross product quadratically, so unlike
//! wordcount or sort the reduce stage — not the map or shuffle stage —
//! can dominate, and key skew in the input shifts the whole `(M, R)`
//! response surface.  No combiner: a cross product is not
//! associatively reducible, so every tagged record must cross the
//! shuffle intact.

use crate::api::{Mapper, Pair, Reducer};

/// Tag prefix a mapper attaches to left-relation values.
const LEFT: &str = "L:";
/// Tag prefix a mapper attaches to right-relation values.
const RIGHT: &str = "R:";

/// Re-keys `L\tkey\tpayload` / `R\tkey\tpayload` records on the join
/// key, carrying the side tag into the value.  Records with an unknown
/// tag or no key column are dropped (dirty input must not poison the
/// join output).
pub struct JoinMapper;

impl Mapper for JoinMapper {
    fn map(&self, _offset: u64, line: &str, out: &mut Vec<Pair>) {
        let Some((tag, rest)) = line.split_once('\t') else {
            return;
        };
        let prefix = match tag {
            "L" => LEFT,
            "R" => RIGHT,
            _ => return,
        };
        let (key, payload) = match rest.split_once('\t') {
            Some((k, p)) => (k, p),
            None => (rest, ""),
        };
        if key.is_empty() {
            return;
        }
        out.push(Pair::new(key, format!("{prefix}{payload}")));
    }
}

/// Cross-products the left and right sides of each key: one output
/// record per `(left, right)` payload pair, in the framework's
/// deterministic value order.  Keys present on only one side emit
/// nothing (inner-join semantics).
pub struct JoinReducer;

impl Reducer for JoinReducer {
    fn reduce(&self, key: &str, values: &[String], out: &mut Vec<Pair>) {
        let left: Vec<&str> =
            values.iter().filter_map(|v| v.strip_prefix(LEFT)).collect();
        let right: Vec<&str> =
            values.iter().filter_map(|v| v.strip_prefix(RIGHT)).collect();
        for l in &left {
            for r in &right {
                out.push(Pair::new(key, format!("{l},{r}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::engine::{execute, ExecOptions};
    use crate::api::traits::HashPartitioner;

    fn opts(r: u32, splits: u32) -> ExecOptions<'static> {
        ExecOptions {
            num_reducers: r,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: splits,
        }
    }

    #[test]
    fn inner_join_cross_products_matching_keys() {
        let input = "L\tk1\ta\nR\tk1\tx\nL\tk1\tb\nR\tk2\ty\nL\tk3\tc\n";
        let out = execute(&JoinMapper, &JoinReducer, input, &opts(2, 2));
        // k1: 2 left × 1 right = 2 rows; k2 and k3 are single-sided.
        assert_eq!(
            out.all_pairs(),
            vec![Pair::new("k1", "a,x"), Pair::new("k1", "b,x")]
        );
    }

    #[test]
    fn hot_keys_multiply_output_quadratically() {
        // 4 left + 4 right records on one key -> 16 join rows.
        let mut input = String::new();
        for i in 0..4 {
            input.push_str(&format!("L\thot\tl{i}\n"));
            input.push_str(&format!("R\thot\tr{i}\n"));
        }
        let out = execute(&JoinMapper, &JoinReducer, &input, &opts(3, 2));
        assert_eq!(out.output_records, 16);
        assert!(out.output_bytes > out.input_bytes / 2);
    }

    #[test]
    fn malformed_records_are_dropped_not_joined() {
        let input = "L\tk\tv\nnot-tagged\nX\tk\tv\nR\tk\tw\nL\t\tempty-key\n";
        let out = execute(&JoinMapper, &JoinReducer, input, &opts(1, 1));
        assert_eq!(out.all_pairs(), vec![Pair::new("k", "v,w")]);
        // Only the two well-formed tagged records crossed the shuffle.
        assert_eq!(out.shuffle_records, 2);
    }

    #[test]
    fn results_stable_across_split_and_reducer_counts() {
        let mut input = String::new();
        for i in 0..30 {
            input.push_str(&format!("L\tk{}\tleft{i}\n", i % 7));
            input.push_str(&format!("R\tk{}\tright{i}\n", i % 5));
        }
        let base = execute(&JoinMapper, &JoinReducer, &input, &opts(1, 1)).all_pairs();
        for r in [2, 5] {
            for s in [3, 8] {
                let got =
                    execute(&JoinMapper, &JoinReducer, &input, &opts(r, s)).all_pairs();
                assert_eq!(got, base, "r={r} s={s}");
            }
        }
    }
}
