//! Benchmark applications (paper §V.A) and their cost profiles.
//!
//! * [`wordcount`] — the paper's first benchmark (Java WordCount);
//! * [`exim`] — the paper's second benchmark (Exim mainlog parsing,
//!   written in Python and run via Hadoop streaming);
//! * [`grep`] — a third app (distributed grep) used by the extension
//!   experiments to show the model generalizes across applications;
//! * [`sort`] — a terasort-like distributed sort, shuffle-bound
//!   (selectivity ≈ 1), the anchor workload for the `shuffle_bytes`
//!   prediction target;
//! * [`join`] — a skew-prone two-input repartition join whose hot-key
//!   cross products make the reduce stage dominant.
//!
//! Each app provides real [`crate::api::Mapper`]/[`crate::api::Reducer`]
//! implementations (functionally executed in tests and examples) plus an
//! [`crate::mr::cost::AppProfile`] for the timed simulator.  Profiles can
//! be re-calibrated from functional runs via [`profiles::calibrate`].

pub mod exim;
pub mod grep;
pub mod join;
pub mod profiles;
pub mod sort;
pub mod wordcount;

use crate::api::{Combiner, Mapper, Reducer};
use crate::mr::cost::AppProfile;

/// The applications known to the framework.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum AppId {
    /// The paper's first benchmark: Java WordCount.
    WordCount,
    /// The paper's second benchmark: Exim mainlog parsing (streaming).
    EximParse,
    /// Extension app: distributed grep.
    Grep,
    /// Extension app: terasort-like distributed sort (shuffle-bound).
    Sort,
    /// Extension app: two-input repartition join (skew-prone).
    Join,
}

impl AppId {
    /// Parse a CLI/JSON app name (accepts common aliases).
    pub fn parse(name: &str) -> Result<AppId, String> {
        match name.to_ascii_lowercase().as_str() {
            "wordcount" | "wc" => Ok(AppId::WordCount),
            "exim" | "eximparse" | "exim-mainlog" => Ok(AppId::EximParse),
            "grep" => Ok(AppId::Grep),
            "sort" | "terasort" => Ok(AppId::Sort),
            "join" | "repartition-join" => Ok(AppId::Join),
            other => Err(format!(
                "unknown app '{other}' (expected wordcount | exim | grep | sort | join)"
            )),
        }
    }

    /// Canonical name (round-trips through [`AppId::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            AppId::WordCount => "wordcount",
            AppId::EximParse => "exim",
            AppId::Grep => "grep",
            AppId::Sort => "sort",
            AppId::Join => "join",
        }
    }

    /// Every application, paper benchmarks first.
    pub fn all() -> [AppId; 5] {
        [AppId::WordCount, AppId::EximParse, AppId::Grep, AppId::Sort, AppId::Join]
    }

    /// The two applications evaluated in the paper.
    pub fn paper_apps() -> [AppId; 2] {
        [AppId::WordCount, AppId::EximParse]
    }

    /// Cost profile for the timed simulator.
    pub fn profile(&self) -> AppProfile {
        match self {
            AppId::WordCount => profiles::wordcount(),
            AppId::EximParse => profiles::exim(),
            AppId::Grep => profiles::grep(),
            AppId::Sort => profiles::sort(),
            AppId::Join => profiles::join(),
        }
    }

    /// Functional implementation (mapper, reducer, optional combiner).
    pub fn functional(
        &self,
    ) -> (Box<dyn Mapper>, Box<dyn Reducer>, Option<Box<dyn Combiner>>) {
        match self {
            AppId::WordCount => (
                Box::new(wordcount::WordCountMapper),
                Box::new(wordcount::WordCountReducer),
                Some(Box::new(wordcount::WordCountReducer)),
            ),
            AppId::EximParse => (
                Box::new(exim::EximMapper),
                Box::new(exim::EximReducer),
                None, // grouping is not associative-reducible
            ),
            AppId::Grep => (
                Box::new(grep::GrepMapper::default()),
                Box::new(grep::GrepReducer),
                Some(Box::new(grep::GrepReducer)),
            ),
            AppId::Sort => (
                Box::new(sort::SortMapper),
                Box::new(sort::SortReducer),
                None, // a sort must keep every record distinct
            ),
            AppId::Join => (
                Box::new(join::JoinMapper),
                Box::new(join::JoinReducer),
                None, // cross products are not associative-reducible
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        for app in AppId::all() {
            assert_eq!(AppId::parse(app.name()).unwrap(), app);
        }
        assert_eq!(AppId::parse("WC").unwrap(), AppId::WordCount);
        assert_eq!(AppId::parse("terasort").unwrap(), AppId::Sort);
        assert_eq!(AppId::parse("repartition-join").unwrap(), AppId::Join);
        assert!(AppId::parse("teragen").is_err());
    }

    #[test]
    fn paper_apps_are_the_evaluated_pair() {
        let [a, b] = AppId::paper_apps();
        assert_eq!(a, AppId::WordCount);
        assert_eq!(b, AppId::EximParse);
    }

    #[test]
    fn profiles_reflect_paper_observations() {
        let wc = AppId::WordCount.profile();
        let ex = AppId::EximParse.profile();
        // Exim runs via Hadoop streaming (Python), WordCount is Java.
        assert!(!wc.streaming);
        assert!(ex.streaming);
        // §V.B: "WordCount has double execution time than Exim main log" —
        // driven by its much heavier per-byte map CPU.
        assert!(wc.map_cpu_ns_per_byte > 1.5 * ex.map_cpu_ns_per_byte);
        // Streaming noise drives Exim's larger prediction error.
        assert!(ex.task_sigma() > wc.task_sigma());
    }

    #[test]
    fn extension_profiles_cover_new_corners() {
        // Sort is the shuffle-bound corner: nearly all input crosses the
        // network and is written back out.
        let sort = AppId::Sort.profile();
        assert!(sort.selectivity > 0.9 && sort.output_ratio > 0.9);
        // Join is the reduce-bound corner: hot-key cross products.
        let join = AppId::Join.profile();
        assert!(join.reduce_cpu_ns_per_byte > join.map_cpu_ns_per_byte);
        // The shuffle-volume ordering the multi-target model must learn.
        assert!(sort.selectivity > AppId::WordCount.profile().selectivity);
        assert!(join.selectivity > AppId::WordCount.profile().selectivity);
    }
}
