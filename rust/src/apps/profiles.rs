//! Cost profiles for the benchmark applications, plus calibration from
//! functional execution.
//!
//! The CPU coefficients are ns-per-byte at a 1 GHz reference clock, set to
//! 2011-era Hadoop throughputs (a few MB/s per core for WordCount-class
//! jobs) and shaped so the simulated surface reproduces the paper's
//! qualitative findings (§V.B): WordCount ≈ 2× Exim total time, both
//! minimal near (20 mappers, 5 reducers), WordCount more fluctuating,
//! Exim noisier run-to-run (streaming).
//!
//! `calibrate` re-derives the *data-dependent* coefficients (selectivity,
//! output ratio) from a real functional run on sampled input, keeping the
//! simulator's data-flow assumptions honest against the actual apps.

use crate::api::engine::JobOutput;
use crate::mr::cost::AppProfile;

/// WordCount (Java): map-CPU heavy (tokenize + emit per word), combiner
/// shrinks shuffle to per-split vocabularies.
pub fn wordcount() -> AppProfile {
    AppProfile {
        name: "wordcount".into(),
        map_cpu_ns_per_byte: 800.0,
        reduce_cpu_ns_per_byte: 500.0,
        selectivity: 0.28,
        output_ratio: 0.05,
        streaming: false,
        noise_sigma: 0.025,
        job_sigma: 0.008,
    }
}

/// Exim mainlog parsing (Python via Hadoop streaming): cheap line parse,
/// but most bytes survive into the shuffle (transaction grouping), plus
/// streaming pipe overhead and doubled temporal noise.
pub fn exim() -> AppProfile {
    AppProfile {
        name: "exim".into(),
        map_cpu_ns_per_byte: 140.0,
        reduce_cpu_ns_per_byte: 30.0,
        selectivity: 0.50,
        output_ratio: 0.45,
        streaming: true,
        noise_sigma: 0.045,
        job_sigma: 0.028,
    }
}

/// Distributed grep (Java): scan-dominated, near-zero selectivity.
pub fn grep() -> AppProfile {
    AppProfile {
        name: "grep".into(),
        map_cpu_ns_per_byte: 90.0,
        reduce_cpu_ns_per_byte: 10.0,
        selectivity: 0.0008,
        output_ratio: 0.0001,
        streaming: false,
        noise_sigma: 0.02,
        job_sigma: 0.008,
    }
}

/// Terasort-like sort (Java): cheap per-byte map/reduce work, but every
/// input byte crosses the shuffle and is written back with replication —
/// the shuffle/network-bound corner of the app space, and the natural
/// benchmark for the `shuffle_bytes` prediction target.
pub fn sort() -> AppProfile {
    AppProfile {
        name: "sort".into(),
        map_cpu_ns_per_byte: 60.0,
        reduce_cpu_ns_per_byte: 40.0,
        selectivity: 0.97,
        output_ratio: 0.97,
        streaming: false,
        noise_sigma: 0.025,
        job_sigma: 0.01,
    }
}

/// Reduce-side repartition join (Java): tagging is cheap, but cross
/// products on Zipf-hot keys make reduce CPU the dominant per-byte cost
/// and inflate run-to-run variance (which reducer draws the hot key).
pub fn join() -> AppProfile {
    AppProfile {
        name: "join".into(),
        map_cpu_ns_per_byte: 120.0,
        reduce_cpu_ns_per_byte: 200.0,
        selectivity: 0.85,
        output_ratio: 0.60,
        streaming: false,
        noise_sigma: 0.03,
        job_sigma: 0.01,
    }
}

/// Recalibrate the data-dependent coefficients of `profile` from a
/// functional run (`out`) on representative sample input.
///
/// Selectivity and output ratio are measured exactly; CPU coefficients are
/// left untouched (they encode the 2011 testbed, not this host).  Returns
/// the calibrated profile and the relative drift of the old selectivity —
/// large drift means the built-in constants disagree with the actual app
/// on this corpus, and the caller may want to re-profile.
pub fn calibrate(profile: &AppProfile, out: &JobOutput) -> (AppProfile, f64) {
    if out.input_bytes == 0 {
        // Nothing measured; leave the profile untouched.
        return (profile.clone(), 0.0);
    }
    let mut p = profile.clone();
    let measured_sel = out.selectivity();
    let drift = if profile.selectivity > 0.0 {
        (measured_sel - profile.selectivity).abs() / profile.selectivity
    } else {
        0.0
    };
    p.selectivity = measured_sel.max(1e-6);
    p.output_ratio = out.output_bytes as f64 / out.input_bytes as f64;
    (p, drift)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::engine::{execute, ExecOptions};
    use crate::api::traits::HashPartitioner;
    use crate::apps::AppId;
    use crate::datagen;
    use crate::util::rng::Rng;

    #[test]
    fn calibrate_measures_selectivity() {
        let mut rng = Rng::new(5);
        let corpus = datagen::corpus::generate(&mut rng, 200_000);
        let (mapper, reducer, combiner) = AppId::WordCount.functional();
        let o = ExecOptions {
            num_reducers: 4,
            combiner: combiner.as_deref(),
            partitioner: &HashPartitioner,
            num_splits: 8,
        };
        let out = execute(mapper.as_ref(), reducer.as_ref(), &corpus, &o);
        let (p, drift) = calibrate(&wordcount(), &out);
        assert!((p.selectivity - out.selectivity()).abs() < 1e-12);
        assert!(p.output_ratio > 0.0);
        // Combiner-era WordCount selectivity is strongly corpus-size
        // dependent (per-split vocabulary / split bytes): at 25 KB splits
        // it sits well above the 8 GB-scale constant in `wordcount()`.  We
        // only assert the measured value is in a sane band and that the
        // drift is reported.
        assert!(p.selectivity > 0.0 && p.selectivity < 2.0);
        assert!(drift.is_finite());
    }

    #[test]
    fn calibrate_handles_empty_run() {
        let out = JobOutput::default();
        let (p, drift) = calibrate(&grep(), &out);
        assert_eq!(p.selectivity, grep().selectivity);
        assert_eq!(drift, 0.0);
    }

    #[test]
    fn exim_selectivity_close_to_measured() {
        let mut rng = Rng::new(6);
        let log = datagen::exim_log::generate(&mut rng, 200_000);
        let (mapper, reducer, _) = AppId::EximParse.functional();
        let o = ExecOptions {
            num_reducers: 4,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: 8,
        };
        let out = execute(mapper.as_ref(), reducer.as_ref(), &log, &o);
        // Most mainlog bytes carry a message id and survive to the shuffle.
        assert!(out.selectivity() > 0.4, "exim selectivity {}", out.selectivity());
        let (p, _) = calibrate(&exim(), &out);
        assert!(p.selectivity > 0.4);
    }
}
