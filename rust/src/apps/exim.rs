//! Exim mainlog parsing — the paper's second benchmark (§V.A, [35]).
//!
//! Exim (a Unix message transfer agent) logs each message's lifecycle in
//! `mainlog`: arrival (`<=`), deliveries (`=>`, `->`), completion
//! (`Completed`), each line tagged with a 16-character message id like
//! `1QdXYZ-0001aB-C1`.  The benchmark groups every line by its message id,
//! producing one record per transaction — the paper's description:
//! "parse the data in an Exim Mainlog file into individual transactions;
//! each separated and arranged by a unique transaction ID".
//!
//! The original ran as a *Python* job under Hadoop streaming, which is why
//! its profile carries streaming overhead and doubled noise (§V.B blames
//! streaming for Exim's larger prediction error).

use crate::api::{Mapper, Pair, Reducer};

/// Extracts the Exim message id from a mainlog line, if present.
///
/// Format: `YYYY-MM-DD HH:MM:SS <id> <rest>` where `<id>` is
/// `xxxxxx-yyyyyy-zz` (6+6+2 base-62 chars).  Lines without an id (e.g.
/// daemon start messages) are ignored, as in the reference parser.
pub fn message_id(line: &str) -> Option<&str> {
    let mut fields = line.split_whitespace();
    let _date = fields.next()?;
    let _time = fields.next()?;
    let id = fields.next()?;
    let b = id.as_bytes();
    if b.len() == 16
        && b[6] == b'-'
        && b[13] == b'-'
        && b.iter().enumerate().all(|(i, &c)| {
            i == 6 || i == 13 || c.is_ascii_alphanumeric()
        })
    {
        Some(id)
    } else {
        None
    }
}

/// Emits `<message_id, line>` for every transaction line.
pub struct EximMapper;

impl Mapper for EximMapper {
    fn map(&self, _offset: u64, line: &str, out: &mut Vec<Pair>) {
        if let Some(id) = message_id(line) {
            out.push(Pair::new(id, line));
        }
    }
}

/// Assembles one transaction record per message id: the log lines sorted
/// chronologically (their timestamp prefix makes lexicographic == temporal)
/// and joined with `|`.
pub struct EximReducer;

impl Reducer for EximReducer {
    fn reduce(&self, key: &str, values: &[String], out: &mut Vec<Pair>) {
        let mut lines: Vec<&String> = values.iter().collect();
        lines.sort();
        let joined = lines
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>()
            .join("|");
        out.push(Pair::new(key, joined));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::engine::{execute, ExecOptions};
    use crate::api::traits::HashPartitioner;

    const SAMPLE: &str = "\
2011-07-04 10:15:32 1QdXYZ-0001aB-C1 <= alice@example.org S=2406
2011-07-04 10:15:33 1QdXYZ-0001aB-C1 => bob@example.net R=dnslookup
2011-07-04 10:15:33 exim 4.69 daemon started
2011-07-04 10:15:34 1QdXYZ-0001aB-C1 Completed
2011-07-04 10:16:01 1QdABC-0002cD-E2 <= carol@example.org S=912
2011-07-04 10:16:02 1QdABC-0002cD-E2 => dave@example.com R=dnslookup
2011-07-04 10:16:03 1QdABC-0002cD-E2 Completed
";

    fn opts() -> ExecOptions<'static> {
        ExecOptions {
            num_reducers: 4,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: 3,
        }
    }

    #[test]
    fn message_id_extraction() {
        assert_eq!(
            message_id("2011-07-04 10:15:32 1QdXYZ-0001aB-C1 <= a@b"),
            Some("1QdXYZ-0001aB-C1")
        );
        assert_eq!(message_id("2011-07-04 10:15:33 exim daemon started"), None);
        assert_eq!(message_id(""), None);
        assert_eq!(message_id("short line"), None);
        // Wrong dash positions.
        assert_eq!(message_id("2011-07-04 10:15:32 1QdXYZ0-001aB-C1 x"), None);
    }

    #[test]
    fn groups_lines_into_transactions() {
        let out = execute(&EximMapper, &EximReducer, SAMPLE, &opts());
        let pairs = out.all_pairs();
        assert_eq!(pairs.len(), 2, "two transactions");
        let t1 = pairs.iter().find(|p| p.key == "1QdXYZ-0001aB-C1").unwrap();
        // Chronological order within the transaction: arrival, delivery,
        // completion.
        let parts: Vec<&str> = t1.value.split('|').collect();
        assert_eq!(parts.len(), 3);
        assert!(parts[0].contains("<="));
        assert!(parts[1].contains("=>"));
        assert!(parts[2].contains("Completed"));
    }

    #[test]
    fn non_transaction_lines_dropped() {
        let out = execute(&EximMapper, &EximReducer, SAMPLE, &opts());
        assert_eq!(out.input_records, 7);
        assert_eq!(out.map_output_records, 6, "daemon line filtered");
    }

    #[test]
    fn result_stable_across_splits_and_reducers() {
        let big = SAMPLE.repeat(30);
        let base = execute(&EximMapper, &EximReducer, &big, &opts()).all_pairs();
        for (r, s) in [(1, 1), (7, 5), (13, 2)] {
            let o = ExecOptions {
                num_reducers: r,
                combiner: None,
                partitioner: &HashPartitioner,
                num_splits: s,
            };
            assert_eq!(execute(&EximMapper, &EximReducer, &big, &o).all_pairs(), base);
        }
    }
}
