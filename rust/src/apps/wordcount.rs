//! WordCount — the paper's first benchmark (§V.A, [33-34]).
//!
//! "Each Mapper picks a line as input and breaks it into words
//! `<word, 1>` ... each Reducer counts the values of pairs with the same
//! key" — the canonical Hadoop example, reproduced here verbatim,
//! including the standard sum combiner.

use crate::api::{Combiner, Mapper, Pair, Reducer};

/// Splits lines into words and emits `<word, 1>`.
pub struct WordCountMapper;

impl Mapper for WordCountMapper {
    fn map(&self, _offset: u64, line: &str, out: &mut Vec<Pair>) {
        for word in line.split_whitespace() {
            // Hadoop's StringTokenizer keeps punctuation; so do we.
            out.push(Pair::new(word, "1"));
        }
    }
}

/// Sums counts per word.  Doubles as the combiner (sum is associative and
/// commutative), exactly like the stock Hadoop example.
pub struct WordCountReducer;

impl Reducer for WordCountReducer {
    fn reduce(&self, key: &str, values: &[String], out: &mut Vec<Pair>) {
        let total: u64 = values.iter().map(|v| v.parse::<u64>().unwrap_or(0)).sum();
        out.push(Pair::new(key, total.to_string()));
    }
}

impl Combiner for WordCountReducer {
    fn combine(&self, key: &str, values: &[String], out: &mut Vec<Pair>) {
        let total: u64 = values.iter().map(|v| v.parse::<u64>().unwrap_or(0)).sum();
        out.push(Pair::new(key, total.to_string()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::engine::{execute, ExecOptions};
    use crate::api::traits::HashPartitioner;

    fn opts(r: u32, combine: bool) -> ExecOptions<'static> {
        ExecOptions {
            num_reducers: r,
            combiner: if combine { Some(&WordCountReducer) } else { None },
            partitioner: &HashPartitioner,
            num_splits: 4,
        }
    }

    #[test]
    fn counts_words() {
        let input = "the quick brown fox\nthe lazy dog\nthe end\n";
        let out = execute(&WordCountMapper, &WordCountReducer, input, &opts(3, true));
        let pairs = out.all_pairs();
        let the = pairs.iter().find(|p| p.key == "the").unwrap();
        assert_eq!(the.value, "3");
        assert_eq!(pairs.iter().filter(|p| p.key == "fox").count(), 1);
        assert_eq!(out.input_records, 3);
    }

    #[test]
    fn combiner_preserves_counts() {
        let input = "a b a\nb a b\n".repeat(40);
        let plain = execute(&WordCountMapper, &WordCountReducer, &input, &opts(4, false));
        let combined = execute(&WordCountMapper, &WordCountReducer, &input, &opts(4, true));
        assert_eq!(plain.all_pairs(), combined.all_pairs());
        assert!(combined.shuffle_bytes < plain.shuffle_bytes);
    }

    #[test]
    fn empty_lines_and_whitespace() {
        let input = "\n\n   \n word \n";
        let out = execute(&WordCountMapper, &WordCountReducer, input, &opts(1, true));
        assert_eq!(out.all_pairs(), vec![Pair::new("word", "1")]);
    }

    #[test]
    fn punctuation_kept_like_stringtokenizer() {
        let input = "end. end\n";
        let out = execute(&WordCountMapper, &WordCountReducer, input, &opts(1, false));
        // "end." and "end" are distinct tokens, as in stock WordCount.
        assert_eq!(out.output_records, 2);
    }
}
