//! Terasort-like distributed sort — the shuffle-heavy extension app.
//!
//! The benchmark that motivated the network-load companion work (arXiv
//! 1206.2016): every input byte crosses the shuffle (selectivity ≈ 1)
//! and every byte is written back out (output ratio ≈ 1), so total
//! execution time is shuffle/network-bound rather than map-CPU-bound —
//! the opposite corner of the `(M, R)` surface from grep.  Mappers emit
//! `<key, payload>` straight from `key\tpayload` records; the framework's
//! sort-by-key between map and reduce does the actual work, and reducers
//! pass records through in key order.

use crate::api::{Mapper, Pair, Reducer};

/// Splits each `key\tpayload` record; lines without a tab sort on the
/// whole line with an empty payload (total, never dropping a record —
/// a sort must not lose input).
pub struct SortMapper;

impl Mapper for SortMapper {
    fn map(&self, _offset: u64, line: &str, out: &mut Vec<Pair>) {
        if line.is_empty() {
            return;
        }
        match line.split_once('\t') {
            Some((key, payload)) => out.push(Pair::new(key, payload)),
            None => out.push(Pair::new(line, "")),
        }
    }
}

/// Emits every payload of a key, in the framework's (deterministic)
/// value order — the identity reduce of a distributed sort.  No
/// combiner: pre-aggregation would merge records a sort must keep.
pub struct SortReducer;

impl Reducer for SortReducer {
    fn reduce(&self, key: &str, values: &[String], out: &mut Vec<Pair>) {
        for v in values {
            out.push(Pair::new(key, v.as_str()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::engine::{execute, ExecOptions};
    use crate::api::traits::HashPartitioner;

    #[test]
    fn passes_every_record_through_in_key_order() {
        let input = "cherry\t3\napple\t1\nbanana\t2\napple\t4\n";
        let o = ExecOptions {
            num_reducers: 1,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: 2,
        };
        let out = execute(&SortMapper, &SortReducer, input, &o);
        assert_eq!(
            out.all_pairs(),
            vec![
                Pair::new("apple", "1"),
                Pair::new("apple", "4"),
                Pair::new("banana", "2"),
                Pair::new("cherry", "3"),
            ]
        );
    }

    #[test]
    fn tabless_lines_survive_as_bare_keys() {
        let o = ExecOptions {
            num_reducers: 2,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: 1,
        };
        let out = execute(&SortMapper, &SortReducer, "zeta\nalpha\t9\n", &o);
        assert_eq!(out.output_records, 2, "no record dropped");
    }

    #[test]
    fn shuffle_carries_essentially_all_input() {
        let input = "k1\tpayload-one\nk2\tpayload-two\nk3\tpayload-three\n";
        let o = ExecOptions {
            num_reducers: 2,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: 1,
        };
        let out = execute(&SortMapper, &SortReducer, input, &o);
        // Selectivity ≈ 1: only the tab separators are shed.
        assert!(out.selectivity() > 0.85, "selectivity {}", out.selectivity());
    }
}
