//! Distributed grep — extension app (not in the paper's evaluation pair).
//!
//! The classic third Hadoop demo: mappers emit matching lines' match
//! counts, reducers aggregate per pattern.  Its cost profile (tiny
//! selectivity, map-scan dominated) stresses a different corner of the
//! (M, R) surface than WordCount/Exim, which the ablation benches use to
//! show the regression generalizes per-application.

use crate::api::{Combiner, Mapper, Pair, Reducer};

/// Emits `<pattern, count>` for every line containing the pattern.
pub struct GrepMapper {
    /// Substring to search each line for.
    pub pattern: String,
}

impl Default for GrepMapper {
    fn default() -> Self {
        // Default pattern mirrors the common "grep for errors" workload.
        GrepMapper { pattern: "error".into() }
    }
}

impl Mapper for GrepMapper {
    fn map(&self, _offset: u64, line: &str, out: &mut Vec<Pair>) {
        let count = line.matches(&self.pattern).count();
        if count > 0 {
            out.push(Pair::new(self.pattern.as_str(), count.to_string()));
        }
    }
}

/// Sums match counts (combiner-compatible).
pub struct GrepReducer;

impl Reducer for GrepReducer {
    fn reduce(&self, key: &str, values: &[String], out: &mut Vec<Pair>) {
        let total: u64 = values.iter().map(|v| v.parse::<u64>().unwrap_or(0)).sum();
        out.push(Pair::new(key, total.to_string()));
    }
}

impl Combiner for GrepReducer {
    fn combine(&self, key: &str, values: &[String], out: &mut Vec<Pair>) {
        let total: u64 = values.iter().map(|v| v.parse::<u64>().unwrap_or(0)).sum();
        out.push(Pair::new(key, total.to_string()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::engine::{execute, ExecOptions};
    use crate::api::traits::HashPartitioner;

    #[test]
    fn counts_matches_including_multiple_per_line() {
        let input = "an error here\nno problem\nerror error\n";
        let o = ExecOptions {
            num_reducers: 2,
            combiner: Some(&GrepReducer),
            partitioner: &HashPartitioner,
            num_splits: 2,
        };
        let out = execute(&GrepMapper::default(), &GrepReducer, input, &o);
        assert_eq!(out.all_pairs(), vec![Pair::new("error", "3")]);
    }

    #[test]
    fn no_matches_empty_output() {
        let o = ExecOptions {
            num_reducers: 1,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: 1,
        };
        let out = execute(&GrepMapper::default(), &GrepReducer, "all fine\n", &o);
        assert_eq!(out.output_records, 0);
    }

    #[test]
    fn custom_pattern() {
        let m = GrepMapper { pattern: "Completed".into() };
        let o = ExecOptions {
            num_reducers: 1,
            combiner: None,
            partitioner: &HashPartitioner,
            num_splits: 1,
        };
        let out = execute(&m, &GrepReducer, "x Completed\ny\n", &o);
        assert_eq!(out.all_pairs(), vec![Pair::new("Completed", "1")]);
    }
}
