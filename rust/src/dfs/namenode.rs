//! NameNode: file -> block map and replica placement policy.

use std::collections::BTreeMap;

use super::block::{Block, BlockId, DEFAULT_BLOCK_BYTES};
use crate::cluster::node::NodeId;
use crate::util::rng::Rng;

/// Metadata for one stored file.
#[derive(Clone, Debug)]
pub struct FileMeta {
    /// Full DFS path.
    pub path: String,
    /// File length in bytes.
    pub len: u64,
    /// Blocks sorted by offset.
    pub blocks: Vec<Block>,
}

impl FileMeta {
    /// Replica-holding nodes for the byte range `[lo, hi)`, most-covering
    /// first.  This is what split-locality scheduling consults.
    ///
    /// Blocks are stored sorted by offset, so the overlapping run is found
    /// by binary search instead of a full scan — this call sits on the
    /// split-planning hot path (perf showed the naive O(blocks) scan per
    /// split at 29% of whole-job simulation time; see EXPERIMENTS.md §Perf).
    pub fn nodes_covering(&self, lo: u64, hi: u64) -> Vec<(NodeId, u64)> {
        // First block whose end extends past `lo`.
        let start = self.blocks.partition_point(|b| b.offset + b.len <= lo);
        // Small flat accumulator: cluster sizes are tiny (<= dozens).
        let mut cover: Vec<(NodeId, u64)> = Vec::with_capacity(8);
        for b in &self.blocks[start..] {
            if b.offset >= hi {
                break;
            }
            let ov = b.overlap(lo, hi);
            if ov > 0 {
                for &r in &b.replicas {
                    match cover.iter_mut().find(|(n, _)| *n == r) {
                        Some(e) => e.1 += ov,
                        None => cover.push((r, ov)),
                    }
                }
            }
        }
        // Sort by coverage descending, node id ascending for determinism.
        cover.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        cover
    }
}

/// The NameNode: tracks all files in the simulated DFS.
#[derive(Clone, Debug)]
pub struct NameNode {
    files: BTreeMap<String, FileMeta>,
    next_block: BlockId,
    num_nodes: usize,
    /// Effective replication factor (clamped to cluster size).
    pub replication: usize,
    /// Block size used for new files.
    pub block_bytes: u64,
}

impl NameNode {
    /// NameNode for a cluster of `num_nodes` with the given replication.
    pub fn new(num_nodes: usize, replication: usize) -> NameNode {
        assert!(num_nodes > 0);
        NameNode {
            files: BTreeMap::new(),
            next_block: 0,
            num_nodes,
            // Effective replication can't exceed the cluster size (the
            // paper's 4-node cluster with default replication 3 is fine).
            replication: replication.min(num_nodes).max(1),
            block_bytes: DEFAULT_BLOCK_BYTES,
        }
    }

    /// Create a file of `len` bytes, placing block replicas with HDFS's
    /// policy shape: first replica on the writer node, remainder on random
    /// distinct nodes (rack-awareness degenerates on a 4-node single rack).
    pub fn create_file(
        &mut self,
        path: &str,
        len: u64,
        writer: NodeId,
        rng: &mut Rng,
    ) -> &FileMeta {
        assert!(writer < self.num_nodes, "writer {writer} out of range");
        let mut blocks = Vec::new();
        let mut off = 0;
        while off < len {
            let blen = self.block_bytes.min(len - off);
            let mut replicas = vec![writer];
            let mut others: Vec<NodeId> =
                (0..self.num_nodes).filter(|&n| n != writer).collect();
            rng.shuffle(&mut others);
            replicas.extend(others.into_iter().take(self.replication - 1));
            blocks.push(Block { id: self.next_block, offset: off, len: blen, replicas });
            self.next_block += 1;
            off += blen;
        }
        // A zero-length file still exists, with no blocks.
        let meta = FileMeta { path: path.to_string(), len, blocks };
        self.files.insert(path.to_string(), meta);
        self.files.get(path).unwrap()
    }

    /// Build (without storing) a balanced-ingest layout — used by the job
    /// runner, which plans splits from it immediately and never needs the
    /// NameNode to retain it (storing + cloning the 128-block metadata
    /// was measurable on the simulation hot path, EXPERIMENTS.md §Perf).
    pub fn plan_balanced_file(&mut self, path: &str, len: u64, rng: &mut Rng) -> FileMeta {
        let saved_next = self.next_block;
        let meta = self.balanced_layout(path, len, rng, saved_next);
        self.next_block = saved_next + meta.blocks.len() as u64;
        meta
    }

    fn balanced_layout(
        &self,
        path: &str,
        len: u64,
        rng: &mut Rng,
        first_block: BlockId,
    ) -> FileMeta {
        let mut next_block = first_block;
        let mut blocks =
            Vec::with_capacity((len / self.block_bytes.max(1) + 1) as usize);
        let mut off = 0;
        let mut primary = 0usize;
        while off < len {
            let blen = self.block_bytes.min(len - off);
            // Rejection-sample the non-primary replicas directly instead of
            // shuffling a scratch Vec per block.
            let mut replicas = Vec::with_capacity(self.replication);
            replicas.push(primary);
            while replicas.len() < self.replication {
                let cand = rng.range_usize(0, self.num_nodes);
                if !replicas.contains(&cand) {
                    replicas.push(cand);
                }
            }
            blocks.push(Block { id: next_block, offset: off, len: blen, replicas });
            next_block += 1;
            off += blen;
            primary = (primary + 1) % self.num_nodes;
        }
        FileMeta { path: path.to_string(), len, blocks }
    }

    /// Create a file whose primary replicas round-robin across the
    /// cluster — the layout of a dataset ingested via a balanced load (the
    /// paper's 8 GB input pre-loaded into HDFS), as opposed to a file
    /// written from one node.
    pub fn create_balanced_file(
        &mut self,
        path: &str,
        len: u64,
        rng: &mut Rng,
    ) -> &FileMeta {
        let meta = self.plan_balanced_file(path, len, rng);
        self.files.insert(path.to_string(), meta);
        self.files.get(path).unwrap()
    }

    /// Metadata for `path`, if it exists.
    pub fn stat(&self, path: &str) -> Option<&FileMeta> {
        self.files.get(path)
    }

    /// Remove `path`; returns whether it existed.
    pub fn delete(&mut self, path: &str) -> bool {
        self.files.remove(path).is_some()
    }

    /// Number of stored files.
    pub fn num_files(&self) -> usize {
        self.files.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn blocks_tile_the_file() {
        let mut nn = NameNode::new(4, 3);
        let mut rng = Rng::new(1);
        let f = nn.create_file("/in", 200 * crate::util::bytes::MB, 0, &mut rng);
        assert_eq!(f.blocks.len(), 4); // 64+64+64+8
        let total: u64 = f.blocks.iter().map(|b| b.len).sum();
        assert_eq!(total, f.len);
        // Contiguous, ordered offsets.
        let mut expect = 0;
        for b in &f.blocks {
            assert_eq!(b.offset, expect);
            expect += b.len;
        }
    }

    #[test]
    fn replication_policy() {
        let mut nn = NameNode::new(4, 3);
        let mut rng = Rng::new(2);
        let f = nn.create_file("/in", 10 * DEFAULT_BLOCK_BYTES, 2, &mut rng);
        for b in &f.blocks {
            assert_eq!(b.replicas.len(), 3);
            assert_eq!(b.replicas[0], 2); // writer-local first replica
            let mut uniq = b.replicas.clone();
            uniq.sort();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "replicas must be distinct nodes");
        }
    }

    #[test]
    fn replication_clamped_to_cluster() {
        let nn = NameNode::new(2, 3);
        assert_eq!(nn.replication, 2);
    }

    #[test]
    fn zero_length_file() {
        let mut nn = NameNode::new(4, 3);
        let mut rng = Rng::new(3);
        let f = nn.create_file("/empty", 0, 0, &mut rng);
        assert!(f.blocks.is_empty());
        assert_eq!(f.len, 0);
        assert!(nn.stat("/empty").is_some());
    }

    #[test]
    fn nodes_covering_ranks_by_overlap() {
        let mut nn = NameNode::new(4, 2);
        let mut rng = Rng::new(4);
        nn.create_file("/in", 3 * DEFAULT_BLOCK_BYTES, 1, &mut rng);
        let f = nn.stat("/in").unwrap();
        // Writer (node 1) holds a replica of every block, so it must rank
        // first for the whole-file range.
        let cover = f.nodes_covering(0, f.len);
        assert_eq!(cover[0].0, 1);
        assert_eq!(cover[0].1, f.len);
    }

    #[test]
    fn delete_and_stat() {
        let mut nn = NameNode::new(4, 3);
        let mut rng = Rng::new(5);
        nn.create_file("/a", 1, 0, &mut rng);
        assert!(nn.stat("/a").is_some());
        assert!(nn.delete("/a"));
        assert!(!nn.delete("/a"));
        assert!(nn.stat("/a").is_none());
    }

    #[test]
    fn prop_every_block_covered_by_replication_factor() {
        forall("dfs replication", 25, |rng| {
            let nodes = rng.range_usize(1, 8);
            let repl = rng.range_usize(1, 5);
            let mut nn = NameNode::new(nodes, repl);
            let len = rng.range_u64(1, 5 * DEFAULT_BLOCK_BYTES);
            let writer = rng.range_usize(0, nodes);
            let f = nn.create_file("/f", len, writer, rng);
            let expect = repl.min(nodes).max(1);
            for b in &f.blocks {
                assert_eq!(b.replicas.len(), expect);
                assert!(b.replicas.iter().all(|&r| r < nodes));
            }
        });
    }
}
