//! HDFS block descriptors.

use crate::cluster::node::NodeId;
use crate::util::bytes::MB;

/// Globally unique block identifier.
pub type BlockId = u64;

/// Hadoop 0.20 default dfs.block.size.
pub const DEFAULT_BLOCK_BYTES: u64 = 64 * MB;

/// One replicated block of a file.
#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    /// Unique id assigned by the NameNode.
    pub id: BlockId,
    /// Byte offset of this block within its file.
    pub offset: u64,
    /// Block length in bytes (the tail block may be short).
    pub len: u64,
    /// Nodes holding a replica (first is the "primary" written locally).
    pub replicas: Vec<NodeId>,
}

impl Block {
    /// Whether `node` holds a replica (the map-locality test).
    pub fn is_local_to(&self, node: NodeId) -> bool {
        self.replicas.contains(&node)
    }

    /// Byte range `[offset, offset + len)` intersected with `[lo, hi)`.
    pub fn overlap(&self, lo: u64, hi: u64) -> u64 {
        let a = self.offset.max(lo);
        let b = (self.offset + self.len).min(hi);
        b.saturating_sub(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk() -> Block {
        Block { id: 0, offset: 100, len: 50, replicas: vec![1, 3] }
    }

    #[test]
    fn locality() {
        let b = blk();
        assert!(b.is_local_to(1));
        assert!(b.is_local_to(3));
        assert!(!b.is_local_to(0));
    }

    #[test]
    fn overlap_cases() {
        let b = blk(); // [100, 150)
        assert_eq!(b.overlap(0, 100), 0); // disjoint left
        assert_eq!(b.overlap(150, 200), 0); // disjoint right
        assert_eq!(b.overlap(0, 125), 25); // left partial
        assert_eq!(b.overlap(125, 300), 25); // right partial
        assert_eq!(b.overlap(110, 120), 10); // inner
        assert_eq!(b.overlap(0, 1000), 50); // containing
    }
}
