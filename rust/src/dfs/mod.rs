//! Simulated HDFS: files, 64 MB blocks, replica placement and locality.
//!
//! Hadoop writes all job input/output to HDFS (paper §V.A).  The pieces
//! that matter for execution-time modeling are (a) which nodes hold
//! replicas of each input split — that drives map-task locality, and (b)
//! the replication write pipeline — that drives output-commit cost.

pub mod block;
pub mod namenode;

pub use block::{Block, BlockId, DEFAULT_BLOCK_BYTES};
pub use namenode::{FileMeta, NameNode};
