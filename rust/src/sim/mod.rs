//! Discrete-event simulation core.
//!
//! A minimal but genuine DES kernel: a virtual clock in integer
//! microseconds (exact ordering, no float ties) and a binary-heap event
//! queue with deterministic FIFO tie-breaking.  The MapReduce framework
//! (`crate::mr`) drives all task lifecycle through this queue.

pub mod engine;
pub mod time;

pub use engine::{EventQueue, Scheduled};
pub use time::SimTime;
