//! Event queue with deterministic ordering.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::SimTime;

/// An event scheduled at `time`; `seq` breaks ties FIFO so simulation
/// results do not depend on heap internals.
#[derive(Clone, Debug)]
pub struct Scheduled<E> {
    /// When the event fires.
    pub time: SimTime,
    /// Scheduling order, for deterministic FIFO tie-breaks.
    pub seq: u64,
    /// The event payload.
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    // mrlint: allow(nan_ordering) — canonical total-order delegation to Ord::cmp
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour inside BinaryHeap (max-heap).
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The simulation event queue.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    next_seq: u64,
    now: SimTime,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue at time zero.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, now: SimTime::ZERO, popped: 0 }
    }

    /// Current simulated time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far (perf counter).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Events still pending.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `at`.  Scheduling in the past
    /// (before `now`) is a simulation bug and panics.
    pub fn push_at(&mut self, at: SimTime, event: E) {
        assert!(
            at >= self.now,
            "event scheduled in the past: {at:?} < now {:?}",
            self.now
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { time: at, seq, event });
    }

    /// Schedule `event` after a delay from now.
    pub fn push_after(&mut self, delay: SimTime, event: E) {
        self.push_at(self.now + delay, event);
    }

    /// Rebase the clock to `at` for a new simulation phase.
    ///
    /// Phase-structured simulations (e.g. a map phase whose stragglers
    /// outlive the point where the next phase logically starts) drain the
    /// queue, then restart the clock at the next phase's origin.  Only
    /// valid on an empty queue — rebasing with events pending would
    /// reorder history.  `popped()` and FIFO sequence numbers continue
    /// across phases.
    pub fn rebase(&mut self, at: SimTime) {
        assert!(
            self.heap.is_empty(),
            "rebase on a non-empty queue ({} events pending)",
            self.heap.len()
        );
        self.now = at;
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let s = self.heap.pop()?;
        debug_assert!(s.time >= self.now);
        self.now = s.time;
        self.popped += 1;
        Some((s.time, s.event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(SimTime(30), "c");
        q.push_at(SimTime(10), "a");
        q.push_at(SimTime(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push_at(SimTime(5), i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(SimTime(10), ());
        q.pop();
        assert_eq!(q.now(), SimTime(10));
        q.push_after(SimTime(5), ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime(15));
        assert_eq!(q.popped(), 2);
    }

    #[test]
    fn rebase_starts_a_new_phase() {
        let mut q = EventQueue::new();
        q.push_at(SimTime(100), ());
        q.pop();
        assert_eq!(q.now(), SimTime(100));
        // Drained: the clock may be rebased backwards for phase 2.
        q.rebase(SimTime(40));
        assert_eq!(q.now(), SimTime(40));
        q.push_after(SimTime(5), ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime(45));
        // popped() spans phases.
        assert_eq!(q.popped(), 2);
    }

    #[test]
    #[should_panic(expected = "non-empty queue")]
    fn rebase_rejects_pending_events() {
        let mut q = EventQueue::new();
        q.push_at(SimTime(10), ());
        q.rebase(SimTime(0));
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push_at(SimTime(10), ());
        q.pop();
        q.push_at(SimTime(5), ());
    }

    #[test]
    fn prop_random_schedules_pop_sorted() {
        forall("eventqueue sorted", 50, |rng: &mut Rng| {
            let mut q = EventQueue::new();
            let n = rng.range_usize(1, 200);
            for i in 0..n {
                q.push_at(SimTime(rng.range_u64(0, 1000)), i);
            }
            let mut last = SimTime::ZERO;
            let mut count = 0;
            while let Some((t, _)) = q.pop() {
                assert!(t >= last);
                last = t;
                count += 1;
            }
            assert_eq!(count, n);
        });
    }

    #[test]
    fn prop_equal_times_preserve_insertion_order() {
        forall("fifo ties", 30, |rng: &mut Rng| {
            let mut q = EventQueue::new();
            let t = SimTime(rng.range_u64(0, 50));
            let n = rng.range_usize(2, 50);
            for i in 0..n {
                q.push_at(t, i);
            }
            let order: Vec<_> =
                std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
            assert_eq!(order, (0..n).collect::<Vec<_>>());
        });
    }
}
