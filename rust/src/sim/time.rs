//! Simulated time as integer microseconds.
//!
//! Integer time makes event ordering exact and platform-independent —
//! float accumulation would make `(seed, config) -> makespan` fragile
//! across optimization levels.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time (microseconds since simulation start).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimTime(pub u64);

impl SimTime {
    /// Simulation start.
    pub const ZERO: SimTime = SimTime(0);

    /// Construct from (possibly fractional) seconds; sub-microsecond
    /// amounts round to nearest.  Negative durations clamp to zero.
    pub fn from_secs(s: f64) -> SimTime {
        if s <= 0.0 {
            return SimTime(0);
        }
        SimTime((s * 1e6).round() as u64)
    }

    /// Construct from whole microseconds.
    pub fn from_micros(us: u64) -> SimTime {
        SimTime(us)
    }

    /// This instant as fractional seconds.
    pub fn as_secs(self) -> f64 {
        self.0 as f64 / 1e6
    }

    /// This instant as whole microseconds.
    pub fn as_micros(self) -> u64 {
        self.0
    }

    /// Later of two instants.
    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }

    /// Earlier of two instants.
    pub fn min(self, other: SimTime) -> SimTime {
        SimTime(self.0.min(other.0))
    }

    /// Saturating difference (durations are non-negative).
    pub fn since(self, earlier: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(earlier.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", crate::util::bytes::fmt_secs(self.as_secs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secs_round_trip() {
        let t = SimTime::from_secs(1.5);
        assert_eq!(t.as_micros(), 1_500_000);
        assert!((t.as_secs() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn negative_clamps() {
        assert_eq!(SimTime::from_secs(-3.0), SimTime::ZERO);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime::from_micros(10);
        let b = SimTime::from_micros(4);
        assert_eq!(a + b, SimTime::from_micros(14));
        assert_eq!(a - b, SimTime::from_micros(6));
        assert_eq!(b - a, SimTime::ZERO); // saturating
        assert_eq!(a.max(b), a);
        assert_eq!(a.min(b), b);
        assert_eq!(a.since(b), SimTime::from_micros(6));
    }

    #[test]
    fn ordering_is_exact() {
        assert!(SimTime(1) < SimTime(2));
        assert_eq!(SimTime(5).max(SimTime(5)), SimTime(5));
    }

    #[test]
    fn display() {
        assert_eq!(SimTime::from_secs(75.0).to_string(), "1m15s");
    }
}
