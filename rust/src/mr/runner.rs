//! Discrete-event simulation of one MapReduce job.
//!
//! Mirrors Hadoop 0.20's execution structure:
//!
//! 1. **Map phase** — slot-limited waves with HDFS locality preference,
//!    per-attempt durations from [`super::cost`] times lognormal noise,
//!    speculative backup attempts for stragglers.
//! 2. **Shuffle** — per-reducer fetch overlapped with the map phase after
//!    slowstart, fair-share network contention, per-map fetch latency,
//!    hash-partition volume skew.
//! 3. **Reduce phase** — slot-limited waves of merge + reduce + replicated
//!    output write.
//!
//! Everything stochastic flows from `config.seed` via forked RNG streams,
//! so a `(cluster, app, config)` triple is exactly reproducible.

use crate::cluster::Cluster;
use crate::sim::{EventQueue, SimTime};
use crate::util::rng::Rng;

use super::config::JobConfig;
use super::context::{JobContext, JOB_SEED_SALT};
use super::fault;
use super::cost::{self, AppProfile, JOB_OVERHEAD_S};
use super::outcome::{Counters, JobResult, TaskStat};
use super::split::SplitPlan;

#[derive(Clone, Debug)]
enum Ev {
    /// A map attempt finished: (task index, attempt id).
    MapDone(u32, u32),
    /// A reduce task finished: task index.
    ReduceDone(u32),
}

/// One task attempt: (attempt id, node, start, expected end, local).
type Attempt = (u32, usize, SimTime, SimTime, bool);

struct MapTask<'a> {
    /// Borrowed from the shared [`JobContext`]: splits are session-level
    /// data, so repetitions must not re-clone 128 plans per run.
    split: &'a SplitPlan,
    done: bool,
    end: SimTime,
    speculated: bool,
    /// Original + at most one speculative backup — fixed storage instead
    /// of a per-task Vec (allocation showed up in the job hot loop).
    attempts: [Option<Attempt>; 2],
    num_attempts: u8,
}

/// Simulate one job run; returns the paper's dependent variable (total
/// execution time) plus the full phase/task breakdown.
///
/// Plans a private [`JobContext`] from the run seed (bit-identical to the
/// historical inline planning) and delegates to [`run_job_in`].  Callers
/// that run the same shape repeatedly — campaigns, grid sweeps, what-if
/// replays — should build one context and use [`run_job_in`] directly
/// (the [`crate::profiler::CampaignExecutor`] does exactly that).
pub fn run_job(cluster: &Cluster, app: &AppProfile, config: &JobConfig) -> JobResult {
    let ctx = JobContext::for_job(cluster, config);
    run_job_in(cluster, app, config, &ctx)
}

/// Simulate one job run against a pre-planned, shared [`JobContext`].
///
/// The context must have been planned for this `(cluster, config)` shape
/// (see [`JobContext::matches`]); only the event simulation — task noise,
/// heartbeats, shuffle skew, run-level "temporal changes" — draws from
/// `config.seed` here, so repetitions can borrow one layout.
pub fn run_job_in(
    cluster: &Cluster,
    app: &AppProfile,
    config: &JobConfig,
    ctx: &JobContext,
) -> JobResult {
    config.validate().expect("invalid job config");
    assert!(
        ctx.matches(cluster, config),
        "JobContext shape {:?} does not match this (cluster, config)",
        ctx.shape()
    );
    // Deterministic fault-injection hook (MRTUNER_FAIL_SPEC): may panic
    // or sleep here, before any simulator state exists, so an injected
    // failure never corrupts and never alters a simulation that runs.
    fault::maybe_inject(&app.name, config.num_mappers, config.num_reducers);
    let rng = Rng::new(config.seed ^ JOB_SEED_SALT);
    // One event queue drives the whole job; its clock (`now()`) is the
    // simulation clock for both phases.
    let mut q: EventQueue<Ev> = EventQueue::new();

    // ---- input layout: planned once in the shared context
    let num_tasks = ctx.shape().map_tasks;

    // ---- per-node slot state (local copy; the shared Cluster is immutable)
    let mut free_map: Vec<u32> = cluster.nodes.iter().map(|n| n.spec.map_slots).collect();
    let mut free_red: Vec<u32> =
        cluster.nodes.iter().map(|n| n.spec.reduce_slots).collect();

    let mut counters = Counters::default();
    let mut maps: Vec<MapTask<'_>> = ctx
        .splits
        .iter()
        .map(|split| MapTask {
            split,
            done: false,
            end: SimTime::ZERO,
            speculated: false,
            attempts: [None, None],
            num_attempts: 0,
        })
        .collect();
    let mut pending: Vec<u32> = (0..num_tasks).collect();
    let mut completed_maps = 0u32;
    let mut map_stats: Vec<TaskStat> = Vec::new();
    let mut noise_rng = rng.fork(2);
    let mut next_attempt = 0u32;

    // Launch a map attempt for task `idx` on `node` at time `now`.
    macro_rules! launch_map {
        ($idx:expr, $node:expr, $now:expr, $spec:expr) => {{
            let idx = $idx as usize;
            let node = $node;
            let local = maps[idx].split.preferred.contains(&node);
            let c = cost::map_cost(
                app,
                &cluster.nodes[node].spec,
                &cluster.network,
                maps[idx].split.len,
                local,
            );
            let noise = noise_rng.lognormal(app.task_sigma());
            // Heartbeat-driven assignment: the slot sits idle until its
            // tracker's next heartbeat (Hadoop 0.20 assigns on heartbeat).
            let hb = noise_rng.f64() * 2.0 * cost::HEARTBEAT_MEAN_S;
            counters.cpu_seconds += (c.cpu_s + c.spill_s) * noise;
            let dur = SimTime::from_secs(c.total_s() * noise + hb);
            let attempt = next_attempt;
            next_attempt += 1;
            let end = $now + dur;
            let slot = maps[idx].num_attempts as usize;
            maps[idx].attempts[slot] = Some((attempt, node, $now, end, local));
            maps[idx].num_attempts += 1;
            free_map[node] -= 1;
            counters.map_spills += (c.spills - 1) as u64;
            if $spec {
                counters.speculative_maps += 1;
            } else if local {
                counters.data_local_maps += 1;
            } else {
                counters.remote_maps += 1;
            }
            q.push_at(end, Ev::MapDone($idx, attempt));
        }};
    }

    // Locality-aware pick: first pending split preferring `node`, else the
    // first pending split (rack/any fallback — one rack here).
    let pick_for = |pending: &mut Vec<u32>, maps: &[MapTask<'_>], node: usize| -> Option<u32> {
        let pos = pending
            .iter()
            .position(|&i| maps[i as usize].split.preferred.contains(&node))
            .or(if pending.is_empty() { None } else { Some(0) })?;
        Some(pending.remove(pos))
    };

    // ---- prime all map slots at job start
    let t0 = SimTime::from_secs(JOB_OVERHEAD_S * 0.7); // setup before first task
    {
        // Deterministic node order; fill every slot while work remains.
        let mut progress = true;
        while progress {
            progress = false;
            for node in 0..cluster.num_nodes() {
                if free_map[node] > 0 {
                    if let Some(idx) = pick_for(&mut pending, &maps, node) {
                        launch_map!(idx, node, t0, false);
                        progress = true;
                    }
                }
            }
        }
    }

    // ---- map-phase event loop
    let slowstart_target =
        ((config.slowstart * num_tasks as f64).ceil() as u32).max(1);
    let mut slowstart_time: Option<SimTime> = None;
    let mut map_phase_end = t0;

    while let Some((_, ev)) = q.pop() {
        let now = q.now();
        let Ev::MapDone(idx, attempt) = ev else {
            unreachable!("reduce events are scheduled only after the map phase")
        };
        let iu = idx as usize;
        // Find this attempt; release its slot.
        let (_, node, start, _, local) = maps[iu]
            .attempts
            .iter()
            .flatten()
            .find(|a| a.0 == attempt)
            .copied()
            .expect("unknown attempt");
        free_map[node] += 1;

        if maps[iu].done {
            // A duplicate (speculative or original) already committed; this
            // attempt is the loser and is simply discarded (Hadoop kills it).
            continue;
        }
        maps[iu].done = true;
        maps[iu].end = now;
        completed_maps += 1;
        map_phase_end = map_phase_end.max(now);
        let first_attempt = maps[iu].attempts[0].expect("original attempt").0;
        let was_speculative =
            maps[iu].num_attempts > 1 && attempt != first_attempt;
        if was_speculative {
            counters.speculative_wins += 1;
        }
        map_stats.push(TaskStat {
            index: idx,
            node,
            start,
            end: now,
            local,
            speculative: attempt != first_attempt,
        });

        if completed_maps >= slowstart_target && slowstart_time.is_none() {
            slowstart_time = Some(now);
        }

        // Refill the freed slot: pending work first, else speculation.
        if let Some(next) = pick_for(&mut pending, &maps, node) {
            launch_map!(next, node, now, false);
        } else if config.speculative {
            // Find the running, un-speculated task with the most remaining
            // time; back it up here if >25% of its span remains.
            let candidate = maps
                .iter()
                .enumerate()
                .filter(|(_, t)| !t.done && !t.speculated && t.num_attempts > 0)
                .map(|(i, t)| {
                    let a = t.attempts[0].unwrap();
                    (i, a.3, a.2)
                })
                .filter(|&(_, exp_end, start)| {
                    exp_end > now
                        && (exp_end.since(now).as_secs())
                            > 0.25 * exp_end.since(start).as_secs()
                })
                .max_by_key(|&(_, exp_end, _)| exp_end);
            if let Some((cand, _, _)) = candidate {
                maps[cand].speculated = true;
                launch_map!(cand as u32, node, now, true);
            }
        }
    }
    assert_eq!(completed_maps, num_tasks, "all maps must finish");
    let slowstart_time = slowstart_time.unwrap_or(map_phase_end);

    // ---- shuffle volumes: hash partitioning gives near-even shares with
    // mild skew; model as noisy weights normalized to total map output.
    let total_shuffle: u64 = maps
        .iter()
        .map(|t| (t.split.len as f64 * app.selectivity) as u64)
        .sum();
    counters.shuffle_bytes = total_shuffle;
    let mut skew_rng = rng.fork(3);
    let mut weights: Vec<f64> = (0..config.num_reducers)
        .map(|_| (1.0 + 0.08 * skew_rng.normal()).max(0.2))
        .collect();
    let wsum: f64 = weights.iter().sum();
    for w in &mut weights {
        *w /= wsum;
    }
    let volumes: Vec<u64> = weights
        .iter()
        .map(|w| (total_shuffle as f64 * w) as u64)
        .collect();

    // ---- reduce phase DES
    // Reducers launch at slowstart (or when a slot frees), fetch overlapped
    // with remaining maps, then merge/reduce/write.  The same queue keeps
    // driving the clock; it is rebased to the slowstart instant because
    // reducers launch before the last (possibly speculative) map event.
    q.rebase(slowstart_time);
    let mut reduce_stats: Vec<TaskStat> = Vec::new();
    let cpu_acc = std::cell::Cell::new(0.0f64);
    let mut red_pending: Vec<u32> = (0..config.num_reducers).collect();
    let mut red_noise = rng.fork(4);
    let nodes = cluster.num_nodes();
    let active_estimate = config
        .num_reducers
        .min(cluster.total_reduce_slots());

    // Snapshot contention: reducers concurrently fetching per node.
    let streams_per_node = active_estimate.div_ceil(nodes as u32).max(1);
    let bw = cluster
        .network
        .transfer_bps(streams_per_node, streams_per_node)
        .min(cluster.network.bisection_bps() / active_estimate.max(1) as f64);

    let launch_reduce = |r: u32,
                             node: usize,
                             start: SimTime,
                             q: &mut EventQueue<Ev>,
                             red_noise: &mut Rng,
                             reduce_stats: &mut Vec<TaskStat>| {
        let vol = volumes[r as usize];
        // Fetch: volume at fair-share bandwidth + per-map fetch round trips.
        let fetch_overhead_s = num_tasks as f64
            * cluster.network.fetch_latency_s
            / config.parallel_copies as f64;
        let fetch_s = vol as f64 / bw + fetch_overhead_s;
        // Cannot complete before the last map's output exists; after that,
        // the tail of the final wave still has to cross the wire.
        let tail_s = (vol as f64 / num_tasks.max(1) as f64) / bw
            + cluster.network.fetch_latency_s;
        let fetch_end = (start + SimTime::from_secs(fetch_s))
            .max(map_phase_end + SimTime::from_secs(tail_s));
        let c = cost::reduce_cost(
            app,
            &cluster.nodes[node].spec,
            &cluster.network,
            vol,
            num_tasks,
            config.merge_factor,
            config.replication,
        );
        let noise = red_noise.lognormal(app.task_sigma());
        let hb = red_noise.f64() * 2.0 * cost::HEARTBEAT_MEAN_S;
        cpu_acc.set(cpu_acc.get() + (c.cpu_s + c.merge_s) * noise);
        let end = fetch_end + SimTime::from_secs(c.total_s() * noise + hb);
        reduce_stats.push(TaskStat {
            index: r,
            node,
            start,
            end,
            local: true,
            speculative: false,
        });
        q.push_at(end, Ev::ReduceDone(r));
    };

    // Prime reduce slots at slowstart, spreading across nodes round-robin.
    {
        let mut progress = true;
        while progress && !red_pending.is_empty() {
            progress = false;
            for node in 0..nodes {
                if free_red[node] > 0 && !red_pending.is_empty() {
                    let r = red_pending.remove(0);
                    free_red[node] -= 1;
                    launch_reduce(
                        r,
                        node,
                        slowstart_time,
                        &mut q,
                        &mut red_noise,
                        &mut reduce_stats,
                    );
                    progress = true;
                }
            }
        }
    }

    let mut last_end = map_phase_end;
    while let Some((_, ev)) = q.pop() {
        let now = q.now();
        let Ev::ReduceDone(r) = ev else { unreachable!() };
        let node = reduce_stats.iter().find(|t| t.index == r).unwrap().node;
        free_red[node] += 1;
        last_end = last_end.max(now);
        if let Some(next) = (!red_pending.is_empty()).then(|| red_pending.remove(0)) {
            free_red[node] -= 1;
            launch_reduce(next, node, now, &mut q, &mut red_noise, &mut reduce_stats);
        }
    }

    counters.cpu_seconds += cpu_acc.get();
    counters.output_bytes = (config.input_bytes as f64 * app.output_ratio) as u64;
    // HDFS traffic: the whole input is read once, the output written
    // `replication` times.  Purely planned — no noise — so equal configs
    // always produce equal byte counters.
    counters.hdfs_bytes =
        config.input_bytes + counters.output_bytes * config.replication as u64;
    counters.events_processed = map_stats.len() as u64 + reduce_stats.len() as u64;

    // Job commit + cleanup, plus whole-run "temporal changes": background
    // processes during this particular execution (paper §V.B) scale the
    // entire run multiplicatively.
    let total = last_end + SimTime::from_secs(JOB_OVERHEAD_S * 0.3);
    let run_noise = rng.fork(5).lognormal(app.run_sigma());
    JobResult {
        // Phase summaries all carry the whole-run factor (background load
        // slows every phase); per-task stats stay in unnoised sim time.
        total_time_s: total.as_secs() * run_noise,
        map_phase_s: map_phase_end.as_secs() * run_noise,
        first_reduce_s: slowstart_time.as_secs() * run_noise,
        maps: map_stats,
        reduces: reduce_stats,
        counters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mr::cost::test_profile;
    use crate::util::bytes::GB;
    use crate::util::prop::forall;

    use crate::mr::config::SplitPolicy;

    /// Direct split policy: these tests exercise slot/wave mechanics and
    /// need the task count to equal the mapper setting exactly.
    fn run(m: u32, r: u32, seed: u64) -> JobResult {
        let cluster = Cluster::paper_cluster();
        let app = test_profile(false);
        let config = JobConfig::paper_default(m, r)
            .with_seed(seed)
            .with_split_policy(SplitPolicy::Direct);
        run_job(&cluster, &app, &config)
    }

    #[test]
    fn hadoop_hint_policy_runs_block_bound_tasks() {
        let cluster = Cluster::paper_cluster();
        let app = test_profile(false);
        // Default paper config: 8 GB / 64 MB blocks -> 128 tasks whatever
        // the mapper hint says (faithful Hadoop 0.20 semantics).
        for hint in [5, 20, 40] {
            let config = JobConfig::paper_default(hint, 5).with_seed(1);
            assert_eq!(config.map_tasks(), 128);
            let res = run_job(&cluster, &app, &config);
            assert_eq!(res.maps.len(), 128, "hint {hint}");
        }
    }

    #[test]
    fn run_job_in_with_for_job_context_matches_run_job() {
        let cluster = Cluster::paper_cluster();
        let app = test_profile(false);
        let config = JobConfig::paper_default(20, 5).with_seed(77);
        let a = run_job(&cluster, &app, &config);
        let ctx = JobContext::for_job(&cluster, &config);
        let b = run_job_in(&cluster, &app, &config, &ctx);
        assert_eq!(a.total_time_s, b.total_time_s);
        assert_eq!(a.counters.shuffle_bytes, b.counters.shuffle_bytes);
        assert_eq!(a.maps.len(), b.maps.len());
        assert_eq!(a.reduces.len(), b.reduces.len());
    }

    #[test]
    fn shared_context_isolates_layout_from_run_noise() {
        let cluster = Cluster::paper_cluster();
        let app = test_profile(false);
        let base = JobConfig::paper_default(20, 5);
        let ctx = JobContext::for_session(&cluster, &base, 9);
        let a = run_job_in(&cluster, &app, &base.clone().with_seed(1), &ctx);
        let b = run_job_in(&cluster, &app, &base.clone().with_seed(2), &ctx);
        assert_ne!(a.total_time_s, b.total_time_s, "run noise still per-seed");
        // Same seed + same context is exactly reproducible.
        let a2 = run_job_in(&cluster, &app, &base.clone().with_seed(1), &ctx);
        assert_eq!(a.total_time_s, a2.total_time_s);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn mismatched_context_rejected() {
        let cluster = Cluster::paper_cluster();
        let app = test_profile(false);
        let config = JobConfig::paper_default(20, 5);
        let mut other = config.clone();
        other.input_bytes /= 2;
        let ctx = JobContext::for_session(&cluster, &other, 1);
        run_job_in(&cluster, &app, &config, &ctx);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = run(20, 5, 7);
        let b = run(20, 5, 7);
        assert_eq!(a.total_time_s, b.total_time_s);
        assert_eq!(a.counters.shuffle_bytes, b.counters.shuffle_bytes);
    }

    #[test]
    fn different_seeds_jitter() {
        let a = run(20, 5, 1);
        let b = run(20, 5, 2);
        assert_ne!(a.total_time_s, b.total_time_s);
        // ...but only modestly (noise, not chaos).
        let ratio = a.total_time_s / b.total_time_s;
        assert!(ratio > 0.7 && ratio < 1.4, "ratio {ratio}");
    }

    #[test]
    fn all_tasks_accounted() {
        let res = run(23, 9, 3);
        // Exactly one committed attempt per map task (winner of original
        // vs speculative backup).
        assert_eq!(res.maps.len(), 23);
        assert_eq!(res.reduces.len(), 9);
        assert_eq!(
            res.counters.data_local_maps + res.counters.remote_maps,
            23
        );
    }

    #[test]
    fn phases_ordered() {
        let res = run(20, 5, 4);
        assert!(res.first_reduce_s <= res.map_phase_s);
        assert!(res.map_phase_s < res.total_time_s);
        // Task stats are in unnoised sim time; the noised total divided by
        // a generous noise bound must still cover the last reduce end.
        let last_reduce = res
            .reduces
            .iter()
            .map(|t| t.end.as_secs())
            .fold(0.0, f64::max);
        assert!(last_reduce > 0.0);
        assert!(res.total_time_s > 0.5 * last_reduce, "run noise out of band");
    }

    #[test]
    fn locality_is_high_with_replication_3() {
        // 3 replicas on 4 nodes: nearly every split has a local home.
        let res = run(40, 5, 5);
        assert!(res.locality_fraction() > 0.8, "{}", res.locality_fraction());
    }

    #[test]
    fn more_mappers_than_slots_waves() {
        let res = run(40, 5, 6);
        // 8 map slots -> expect ~5 waves; starts must not all be at t0.
        let starts: Vec<f64> =
            res.maps.iter().map(|t| t.start.as_secs()).collect();
        let earliest = starts.iter().cloned().fold(f64::INFINITY, f64::min);
        let latest = starts.iter().cloned().fold(0.0, f64::max);
        assert!(latest > earliest + 1.0, "waves must stagger starts");
    }

    #[test]
    fn shuffle_bytes_match_selectivity() {
        let res = run(16, 8, 8);
        let expect = (8.0 * GB as f64 * 0.3) as u64;
        let got = res.counters.shuffle_bytes;
        let rel = (got as f64 - expect as f64).abs() / expect as f64;
        assert!(rel < 0.01, "shuffle {got} vs {expect}");
    }

    #[test]
    fn single_mapper_single_reducer() {
        let res = run(1, 1, 9);
        assert_eq!(res.maps.iter().filter(|t| !t.speculative).count(), 1);
        assert_eq!(res.reduces.len(), 1);
        assert!(res.total_time_s > 0.0);
    }

    #[test]
    fn speculation_toggle_changes_nothing_when_off() {
        let cluster = Cluster::paper_cluster();
        let app = test_profile(false);
        let mut config = JobConfig::paper_default(20, 5)
            .with_seed(11)
            .with_split_policy(SplitPolicy::Direct);
        config.speculative = false;
        let res = run_job(&cluster, &app, &config);
        assert_eq!(res.counters.speculative_maps, 0);
        assert!(res.maps.iter().all(|t| !t.speculative));
    }

    #[test]
    fn prop_makespan_bounds() {
        forall("makespan sane", 20, |rng| {
            let m = rng.range_u64(1, 48) as u32;
            let r = rng.range_u64(1, 48) as u32;
            let res = run(m, r, rng.next_u64());
            // Sanity window: longer than fixed overheads, shorter than a
            // serial execution of everything on the slowest node.
            assert!(res.total_time_s > JOB_OVERHEAD_S);
            assert!(
                res.total_time_s < 50_000.0,
                "m={m} r={r}: {}",
                res.total_time_s
            );
            // Reduce phase must end at/after map phase.
            assert!(res.total_time_s >= res.map_phase_s);
        });
    }

    #[test]
    fn prop_noise_free_config_monotone_slots() {
        // With noise suppressed, a cluster with more map slots can't be
        // slower for the same job.
        forall("slots monotone", 8, |rng| {
            let m = rng.range_u64(8, 40) as u32;
            let mut app = test_profile(false);
            app.noise_sigma = 0.0;
            let config = JobConfig::paper_default(m, 5)
                .with_seed(1)
                .with_split_policy(SplitPolicy::Direct);
            let small = Cluster::paper_cluster();
            let mut big = Cluster::paper_cluster();
            for n in &mut big.nodes {
                n.spec.map_slots += 2;
            }
            let t_small = run_job(&small, &app, &config).total_time_s;
            let t_big = run_job(&big, &app, &config).total_time_s;
            assert!(
                t_big <= t_small * 1.02,
                "m={m}: big {t_big} vs small {t_small}"
            );
        });
    }
}
