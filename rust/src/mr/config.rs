//! Job configuration — the paper's tunable parameters plus the fixed
//! Hadoop knobs that shape the cost model.

use crate::util::bytes::{GB, MB};

/// How `num_mappers` maps to actual map-task count.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SplitPolicy {
    /// Faithful Hadoop 0.20 `FileInputFormat` semantics:
    /// `mapred.map.tasks` is a *hint*; the split size is
    /// `min(input/hint, block_bytes)`, so for the paper's 8 GB input and
    /// 64 MB blocks every setting in 5..=40 yields ~128 map tasks.  This
    /// is why the paper's surface is smooth enough for a cubic to fit to
    /// <1% on WordCount — and why the authors could not explain their
    /// "optimal" mapper count ("the reason ... is not clear", §V.B): the
    /// parameter's structural effect is null in that range, leaving noise.
    HadoopHint {
        /// HDFS block size used as the split-size ceiling.
        block_bytes: u64,
    },
    /// `num_mappers` sets the split count exactly (modern engines; also
    /// the naive reading of the paper).  Exposes slot-wave quantization
    /// cliffs that a cubic cannot fit — quantified in the ablation bench.
    Direct,
}

impl SplitPolicy {
    /// Actual number of map tasks for an input of `input_bytes`.
    pub fn task_count(&self, hint: u32, input_bytes: u64) -> u32 {
        match self {
            SplitPolicy::Direct => hint.max(1),
            SplitPolicy::HadoopHint { block_bytes } => {
                let goal = (input_bytes / hint.max(1) as u64).max(1);
                let split = goal.min(*block_bytes).max(1);
                input_bytes.div_ceil(split).max(1) as u32
            }
        }
    }
}

/// MapReduce job configuration.  The paper studies `num_mappers` and
/// `num_reducers` (its two "main configuration parameters", §I); the rest
/// mirror Hadoop 0.20.2 defaults and stay fixed during profiling.
#[derive(Clone, Debug, PartialEq)]
pub struct JobConfig {
    /// Number of map tasks == number of input splits (the paper treats
    /// this as a directly set parameter, range 5..=40).
    pub num_mappers: u32,
    /// Number of reduce tasks (range 5..=40).
    pub num_reducers: u32,
    /// Total input size; the paper profiles on 8 GB.
    pub input_bytes: u64,
    /// HDFS replication for job output (dfs.replication).
    pub replication: usize,
    /// Fraction of maps that must finish before reducers may launch
    /// (mapred.reduce.slowstart.completed.maps).
    pub slowstart: f64,
    /// Enable speculative re-execution of straggler maps.
    pub speculative: bool,
    /// Maximum parallel fetch threads per reducer
    /// (mapred.reduce.parallel.copies).
    pub parallel_copies: u32,
    /// Merge fan-in for the sort phases (io.sort.factor).
    pub merge_factor: u32,
    /// RNG seed for this run — distinct seeds model distinct wall-clock
    /// runs of the same experiment (the paper runs each config 5×).
    pub seed: u64,
    /// How `num_mappers` translates to actual map tasks (see
    /// [`SplitPolicy`]).
    pub split_policy: SplitPolicy,
}

impl JobConfig {
    /// The paper's experimental default: 8 GB input, Hadoop 0.20 knobs.
    pub fn paper_default(num_mappers: u32, num_reducers: u32) -> JobConfig {
        JobConfig {
            num_mappers,
            num_reducers,
            input_bytes: 8 * GB,
            replication: 3,
            slowstart: 0.05,
            speculative: true,
            parallel_copies: 5,
            merge_factor: 10,
            seed: 0,
            split_policy: SplitPolicy::HadoopHint { block_bytes: 64 * MB },
        }
    }

    /// Builder: same config with a different run seed.
    pub fn with_seed(mut self, seed: u64) -> JobConfig {
        self.seed = seed;
        self
    }

    /// Builder: same config with a different [`SplitPolicy`].
    pub fn with_split_policy(mut self, policy: SplitPolicy) -> JobConfig {
        self.split_policy = policy;
        self
    }

    /// Actual map-task count this config produces.
    pub fn map_tasks(&self) -> u32 {
        self.split_policy.task_count(self.num_mappers, self.input_bytes)
    }

    /// Reject degenerate configurations before they reach the simulator.
    pub fn validate(&self) -> Result<(), String> {
        if self.num_mappers == 0 {
            return Err("num_mappers must be >= 1".into());
        }
        if self.num_reducers == 0 {
            return Err("num_reducers must be >= 1".into());
        }
        if self.input_bytes == 0 {
            return Err("input_bytes must be > 0".into());
        }
        if !(0.0..=1.0).contains(&self.slowstart) {
            return Err("slowstart must be in [0,1]".into());
        }
        if self.parallel_copies == 0 || self.merge_factor < 2 {
            return Err("parallel_copies >= 1, merge_factor >= 2".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_values() {
        let c = JobConfig::paper_default(20, 5);
        assert_eq!(c.num_mappers, 20);
        assert_eq!(c.num_reducers, 5);
        assert_eq!(c.input_bytes, 8 * GB);
        assert_eq!(c.replication, 3);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_catches_degenerate_configs() {
        let mut c = JobConfig::paper_default(20, 5);
        c.num_mappers = 0;
        assert!(c.validate().is_err());
        let mut c = JobConfig::paper_default(20, 5);
        c.slowstart = 1.5;
        assert!(c.validate().is_err());
        let mut c = JobConfig::paper_default(20, 5);
        c.merge_factor = 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn with_seed_changes_only_seed() {
        let a = JobConfig::paper_default(10, 10);
        let b = a.clone().with_seed(99);
        assert_eq!(b.seed, 99);
        assert_eq!(a.num_mappers, b.num_mappers);
    }
}
