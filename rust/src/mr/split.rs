//! Input split planning: file byte ranges + locality candidates.

use crate::cluster::node::NodeId;
use crate::dfs::FileMeta;

/// One planned input split.
#[derive(Clone, Debug)]
pub struct SplitPlan {
    /// Split index (== map task index).
    pub index: u32,
    /// Byte offset within the input file.
    pub offset: u64,
    /// Split length in bytes.
    pub len: u64,
    /// Nodes holding replicas of (most of) this split, best first.
    pub preferred: Vec<NodeId>,
}

/// Divide `file` into `n` equal byte ranges and attach locality hints.
///
/// The paper sets the number of mappers directly, so split count == map
/// count (in real Hadoop this is `min(splits, mapred.map.tasks)`-ish; for
/// the studied range the identity holds).
pub fn plan_splits(file: &FileMeta, n: u32) -> Vec<SplitPlan> {
    let n = n.max(1);
    let base = file.len / n as u64;
    let rem = file.len % n as u64;
    let mut out = Vec::with_capacity(n as usize);
    let mut off = 0;
    for i in 0..n {
        // Distribute the remainder over the first `rem` splits so sizes
        // differ by at most one byte.
        let len = base + if (i as u64) < rem { 1 } else { 0 };
        // Hadoop reports at most 3 locations per split (the hosts covering
        // the most bytes); schedulers treat only those as "local".
        let preferred = file
            .nodes_covering(off, off + len)
            .into_iter()
            .take(3)
            .map(|(node, _)| node)
            .collect();
        out.push(SplitPlan { index: i, offset: off, len, preferred });
        off += len;
    }
    debug_assert_eq!(off, file.len);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfs::NameNode;
    use crate::util::bytes::MB;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn file(len: u64, seed: u64) -> FileMeta {
        let mut nn = NameNode::new(4, 3);
        let mut rng = Rng::new(seed);
        nn.create_file("/in", len, 0, &mut rng).clone()
    }

    #[test]
    fn splits_tile_file_evenly() {
        let f = file(1000 * MB, 1);
        let splits = plan_splits(&f, 7);
        assert_eq!(splits.len(), 7);
        let total: u64 = splits.iter().map(|s| s.len).sum();
        assert_eq!(total, f.len);
        let max = splits.iter().map(|s| s.len).max().unwrap();
        let min = splits.iter().map(|s| s.len).min().unwrap();
        assert!(max - min <= 1, "even split sizes");
    }

    #[test]
    fn splits_are_contiguous() {
        let f = file(123_456_789, 2);
        let splits = plan_splits(&f, 13);
        let mut expect = 0;
        for s in &splits {
            assert_eq!(s.offset, expect);
            expect += s.len;
        }
        assert_eq!(expect, f.len);
    }

    #[test]
    fn preferred_nodes_hold_replicas() {
        let f = file(640 * MB, 3);
        for s in plan_splits(&f, 10) {
            assert!(!s.preferred.is_empty());
            // Writer node 0 replicates every block, so it must appear.
            assert!(s.preferred.contains(&0));
        }
    }

    #[test]
    fn prop_any_file_any_split_count() {
        forall("split planning", 40, |rng| {
            let len = rng.range_u64(1, 4_000_000_000);
            let n = rng.range_u64(1, 64) as u32;
            let f = file(len, rng.next_u64());
            let splits = plan_splits(&f, n);
            assert_eq!(splits.len(), n as usize);
            assert_eq!(splits.iter().map(|s| s.len).sum::<u64>(), len);
            for w in splits.windows(2) {
                assert_eq!(w[0].offset + w[0].len, w[1].offset);
            }
        });
    }
}
