//! The timed MapReduce framework: a discrete-event Hadoop-0.20 model.
//!
//! `runner::run_job` simulates one job on a [`crate::cluster::Cluster`]:
//! input splits with HDFS locality, slot-based map scheduling in waves,
//! spill/merge on the map side, shuffle with network contention overlapped
//! with the map phase (slowstart), merge + reduce + replicated output
//! write, speculative execution of stragglers, and multiplicative
//! run-to-run noise ("temporal changes", paper §IV.A).
//!
//! The *functional* counterpart (what the job computes) lives in
//! [`crate::api::engine`]; both execute the same app definitions.

pub mod config;
pub mod context;
pub mod cost;
pub mod fault;
pub mod outcome;
pub mod runner;
pub mod split;

pub use config::JobConfig;
pub use context::{ContextShape, JobContext};
pub use outcome::{JobResult, RepBytes, RepOutcome, TaskStat};
pub use runner::{run_job, run_job_in};
pub use split::{plan_splits, SplitPlan};
