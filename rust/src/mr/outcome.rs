//! Job execution results: makespan, phase breakdown and counters.

use crate::sim::SimTime;

/// Per-task-attempt record (kept for diagnostics and the report module).
#[derive(Clone, Debug)]
pub struct TaskStat {
    /// Task index within its phase.
    pub index: u32,
    /// Node the committed attempt ran on.
    pub node: usize,
    /// Launch time.
    pub start: SimTime,
    /// Commit time.
    pub end: SimTime,
    /// Whether the input read was data-local (maps only).
    pub local: bool,
    /// Whether the committed attempt was speculative.
    pub speculative: bool,
}

impl TaskStat {
    /// Wall-clock duration of the committed attempt.
    pub fn duration_s(&self) -> f64 {
        self.end.since(self.start).as_secs()
    }
}

/// Aggregate counters, mirroring Hadoop's JobCounters.
#[derive(Clone, Debug, Default)]
pub struct Counters {
    /// Maps that read their split from a local replica.
    pub data_local_maps: u64,
    /// Maps that fetched their split over the network.
    pub remote_maps: u64,
    /// Speculative map attempts launched.
    pub speculative_maps: u64,
    /// Speculative attempts that beat the original.
    pub speculative_wins: u64,
    /// Map-side spill passes across all tasks.
    pub map_spills: u64,
    /// Bytes crossing the shuffle.
    pub shuffle_bytes: u64,
    /// Bytes written to the replicated output.
    pub output_bytes: u64,
    /// Bytes moved through HDFS: the input read plus the replicated
    /// output write (`output_bytes × replication`).
    pub hdfs_bytes: u64,
    /// Discrete events processed by the simulator.
    pub events_processed: u64,
    /// Total CPU-seconds consumed by committed task attempts — the
    /// quantity the authors' companion work [24] models ("total CPU tick
    /// clocks"); reproduced by the `cpu-model` extension experiment.
    pub cpu_seconds: f64,
}

/// Deterministic byte counters of one repetition: the shuffle volume the
/// network-provisioning companion work (arXiv 1206.2016) regresses
/// against the same `(M, R)` configuration plane, plus total HDFS
/// traffic (input read + replicated output write).  Both are planned
/// quantities — splits × selectivity and input/output × replication —
/// with no noise applied, so equal keys always carry equal bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RepBytes {
    /// Bytes crossing the shuffle ([`Counters::shuffle_bytes`]).
    pub shuffle: u64,
    /// Bytes moved through HDFS: the input read plus the replicated
    /// output write ([`Counters::hdfs_bytes`]).
    pub hdfs: u64,
}

/// The per-repetition slice of a [`JobResult`] that the profiling layers
/// cache and persist: the paper's dependent variable (total execution
/// time) plus the companion works' modeled outputs (total CPU seconds,
/// [24]'s "CPU tick clocks", and the shuffle/HDFS byte counters of the
/// network-load companion work).
///
/// `cpu_s` is `None` only for records migrated from version-1 profile
/// stores, which predate CPU capture; `bytes` is `None` for records
/// migrated from any pre-v4 store (v1–v3 predate byte capture) and for
/// quarantined sentinel outcomes.  Everything the simulator produces
/// carries all three figures.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RepOutcome {
    /// Total execution time in seconds.
    pub time_s: f64,
    /// Total CPU-seconds, when recorded.
    pub cpu_s: Option<f64>,
    /// Shuffle/HDFS byte counters, when recorded.
    pub bytes: Option<RepBytes>,
}

impl RepOutcome {
    /// Outcome carrying time and CPU but no byte counters (a record
    /// migrated from a v2/v3 profile store, or the quarantine sentinel).
    pub fn full(time_s: f64, cpu_s: f64) -> RepOutcome {
        RepOutcome { time_s, cpu_s: Some(cpu_s), bytes: None }
    }

    /// Time-only outcome (a record migrated from a v1 profile store).
    pub fn time_only(time_s: f64) -> RepOutcome {
        RepOutcome { time_s, cpu_s: None, bytes: None }
    }

    /// Outcome carrying every modeled output — what the simulator
    /// produces since store format v4.
    pub fn with_bytes(time_s: f64, cpu_s: f64, bytes: RepBytes) -> RepOutcome {
        RepOutcome { time_s, cpu_s: Some(cpu_s), bytes: Some(bytes) }
    }

    /// Bit-level equality, NaN-safe — the store's dedup predicate.
    pub fn same_bits(&self, other: &RepOutcome) -> bool {
        self.time_s.to_bits() == other.time_s.to_bits()
            && match (self.cpu_s, other.cpu_s) {
                (Some(a), Some(b)) => a.to_bits() == b.to_bits(),
                (None, None) => true,
                _ => false,
            }
            // u64 equality is already exact; no NaN subtlety for bytes.
            && self.bytes == other.bytes
    }

    /// Whether storing `self` over `old` would *lose* a recorded figure:
    /// a CPU-less outcome over a CPU-carrying record (v1-era data over
    /// v2+), or a bytes-less outcome over a bytes-carrying record
    /// (pre-v4 data over v4).  Both store backends refuse exactly this —
    /// a partial record never displaces a fuller one.
    pub fn downgrades(&self, old: &RepOutcome) -> bool {
        (old.cpu_s.is_some() && self.cpu_s.is_none())
            || (old.bytes.is_some() && self.bytes.is_none())
    }

    /// Whether storing `self` over `old` *adds* a previously missing
    /// figure (CPU or bytes) — the in-place migration the backends
    /// journal so tailing readers see the upgraded record.
    pub fn upgrades(&self, old: &RepOutcome) -> bool {
        (old.cpu_s.is_none() && self.cpu_s.is_some())
            || (old.bytes.is_none() && self.bytes.is_some())
    }
}

/// The outcome of one simulated job execution.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Total execution time — the paper's dependent variable T.
    pub total_time_s: f64,
    /// End of the map phase (all maps committed).
    pub map_phase_s: f64,
    /// Time when the first reducer launched (slowstart).
    pub first_reduce_s: f64,
    /// Committed map attempts, one per task.
    pub maps: Vec<TaskStat>,
    /// Committed reduce attempts, one per task.
    pub reduces: Vec<TaskStat>,
    /// Aggregate Hadoop-style counters.
    pub counters: Counters,
}

impl JobResult {
    /// The per-rep outcome profiling caches and persists for this run.
    pub fn rep_outcome(&self) -> RepOutcome {
        RepOutcome::with_bytes(
            self.total_time_s,
            self.counters.cpu_seconds,
            RepBytes {
                shuffle: self.counters.shuffle_bytes,
                hdfs: self.counters.hdfs_bytes,
            },
        )
    }

    /// Map waves actually executed (`maps` holds one committed attempt per
    /// task).
    pub fn map_waves(&self, total_slots: u32) -> u32 {
        (self.maps.len() as u32).div_ceil(total_slots.max(1))
    }

    /// Fraction of (non-speculative) maps that ran data-local.
    pub fn locality_fraction(&self) -> f64 {
        let c = &self.counters;
        let total = c.data_local_maps + c.remote_maps;
        if total == 0 {
            0.0
        } else {
            c.data_local_maps as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_duration() {
        let t = TaskStat {
            index: 0,
            node: 1,
            start: SimTime::from_secs(2.0),
            end: SimTime::from_secs(5.5),
            local: true,
            speculative: false,
        };
        assert!((t.duration_s() - 3.5).abs() < 1e-9);
    }

    #[test]
    fn rep_outcome_distills_time_cpu_and_bytes() {
        let mut r = JobResult {
            total_time_s: 123.5,
            map_phase_s: 0.0,
            first_reduce_s: 0.0,
            maps: vec![],
            reduces: vec![],
            counters: Counters::default(),
        };
        r.counters.cpu_seconds = 456.25;
        r.counters.shuffle_bytes = 1 << 30;
        r.counters.hdfs_bytes = 3 << 30;
        let o = r.rep_outcome();
        assert_eq!(
            o,
            RepOutcome::with_bytes(
                123.5,
                456.25,
                RepBytes { shuffle: 1 << 30, hdfs: 3 << 30 }
            )
        );
        assert!(o.same_bits(&o));
        assert!(!o.same_bits(&RepOutcome::full(123.5, 456.25)));
        assert!(!o.same_bits(&RepOutcome::time_only(123.5)));
        // NaN-safe: identical NaN bits compare equal.
        let n = RepOutcome::time_only(f64::NAN);
        assert!(n.same_bits(&RepOutcome::time_only(f64::NAN)));
    }

    #[test]
    fn downgrade_and_upgrade_predicates() {
        let b = RepBytes { shuffle: 7, hdfs: 11 };
        let v1 = RepOutcome::time_only(10.0);
        let v2 = RepOutcome::full(10.0, 2.0);
        let v4 = RepOutcome::with_bytes(10.0, 2.0, b);
        // A partial record never displaces a fuller one...
        assert!(v1.downgrades(&v2));
        assert!(v1.downgrades(&v4));
        assert!(v2.downgrades(&v4));
        // ...and filling in a missing figure is an upgrade.
        assert!(v2.upgrades(&v1));
        assert!(v4.upgrades(&v2));
        assert!(v4.upgrades(&v1));
        assert!(!v2.downgrades(&v1));
        assert!(!v4.downgrades(&v4));
        assert!(!v2.upgrades(&v4));
        assert!(!v4.upgrades(&v4));
    }

    #[test]
    fn locality_fraction_handles_zero() {
        let r = JobResult {
            total_time_s: 0.0,
            map_phase_s: 0.0,
            first_reduce_s: 0.0,
            maps: vec![],
            reduces: vec![],
            counters: Counters::default(),
        };
        assert_eq!(r.locality_fraction(), 0.0);
        assert_eq!(r.map_waves(8), 0);
    }
}
