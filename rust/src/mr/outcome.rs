//! Job execution results: makespan, phase breakdown and counters.

use crate::sim::SimTime;

/// Per-task-attempt record (kept for diagnostics and the report module).
#[derive(Clone, Debug)]
pub struct TaskStat {
    /// Task index within its phase.
    pub index: u32,
    /// Node the committed attempt ran on.
    pub node: usize,
    /// Launch time.
    pub start: SimTime,
    /// Commit time.
    pub end: SimTime,
    /// Whether the input read was data-local (maps only).
    pub local: bool,
    /// Whether the committed attempt was speculative.
    pub speculative: bool,
}

impl TaskStat {
    /// Wall-clock duration of the committed attempt.
    pub fn duration_s(&self) -> f64 {
        self.end.since(self.start).as_secs()
    }
}

/// Aggregate counters, mirroring Hadoop's JobCounters.
#[derive(Clone, Debug, Default)]
pub struct Counters {
    /// Maps that read their split from a local replica.
    pub data_local_maps: u64,
    /// Maps that fetched their split over the network.
    pub remote_maps: u64,
    /// Speculative map attempts launched.
    pub speculative_maps: u64,
    /// Speculative attempts that beat the original.
    pub speculative_wins: u64,
    /// Map-side spill passes across all tasks.
    pub map_spills: u64,
    /// Bytes crossing the shuffle.
    pub shuffle_bytes: u64,
    /// Bytes written to the replicated output.
    pub output_bytes: u64,
    /// Discrete events processed by the simulator.
    pub events_processed: u64,
    /// Total CPU-seconds consumed by committed task attempts — the
    /// quantity the authors' companion work [24] models ("total CPU tick
    /// clocks"); reproduced by the `cpu-model` extension experiment.
    pub cpu_seconds: f64,
}

/// The outcome of one simulated job execution.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// Total execution time — the paper's dependent variable T.
    pub total_time_s: f64,
    /// End of the map phase (all maps committed).
    pub map_phase_s: f64,
    /// Time when the first reducer launched (slowstart).
    pub first_reduce_s: f64,
    /// Committed map attempts, one per task.
    pub maps: Vec<TaskStat>,
    /// Committed reduce attempts, one per task.
    pub reduces: Vec<TaskStat>,
    /// Aggregate Hadoop-style counters.
    pub counters: Counters,
}

impl JobResult {
    /// Map waves actually executed (`maps` holds one committed attempt per
    /// task).
    pub fn map_waves(&self, total_slots: u32) -> u32 {
        (self.maps.len() as u32).div_ceil(total_slots.max(1))
    }

    /// Fraction of (non-speculative) maps that ran data-local.
    pub fn locality_fraction(&self) -> f64 {
        let c = &self.counters;
        let total = c.data_local_maps + c.remote_maps;
        if total == 0 {
            0.0
        } else {
            c.data_local_maps as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_duration() {
        let t = TaskStat {
            index: 0,
            node: 1,
            start: SimTime::from_secs(2.0),
            end: SimTime::from_secs(5.5),
            local: true,
            speculative: false,
        };
        assert!((t.duration_s() - 3.5).abs() < 1e-9);
    }

    #[test]
    fn locality_fraction_handles_zero() {
        let r = JobResult {
            total_time_s: 0.0,
            map_phase_s: 0.0,
            first_reduce_s: 0.0,
            maps: vec![],
            reduces: vec![],
            counters: Counters::default(),
        };
        assert_eq!(r.locality_fraction(), 0.0);
        assert_eq!(r.map_waves(8), 0);
    }
}
