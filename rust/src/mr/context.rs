//! Shared per-job setup: the HDFS block layout and the input split plan.
//!
//! Profiling campaigns run the *same job shape* hundreds of times (5 reps
//! per setting, 64+ settings per grid sweep), and under the default
//! [`super::config::SplitPolicy::HadoopHint`] every setting in the paper's
//! 5..=40 range even shares one task count.  Re-planning the NameNode
//! placement and the splits on every repetition was pure waste — and it is
//! also unfaithful: the paper ingests its 8 GB input into HDFS **once**
//! and then profiles against that fixed layout.
//!
//! A [`JobContext`] captures that once-per-session work.  It is built per
//! `(cluster, config shape)` and borrowed by [`super::runner::run_job_in`];
//! the [`crate::profiler::CampaignExecutor`] shares one context across all
//! repetitions and worker threads of a campaign.

use crate::cluster::Cluster;
use crate::dfs::{FileMeta, NameNode};
use crate::util::rng::{splitmix64, Rng};

use super::config::JobConfig;
use super::split::{plan_splits, SplitPlan};

/// Salt mixed into `config.seed` to derive the per-run RNG root (shared
/// with the runner so standalone `run_job` keeps its historical streams).
pub(crate) const JOB_SEED_SALT: u64 = 0x6a6f_625f_7275_6e73;

/// Fork stream id historically used for the input-layout RNG.
pub(crate) const LAYOUT_STREAM: u64 = 1;

/// The configuration fields that determine the input layout and split
/// plan.  Two configs with equal shapes can share one [`JobContext`]:
/// everything else (`seed`, reducer count, slowstart, speculation, ...)
/// only affects the event simulation, never the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ContextShape {
    /// Cluster size the layout was planned for.
    pub num_nodes: usize,
    /// HDFS replication factor.
    pub replication: usize,
    /// Total input size.
    pub input_bytes: u64,
    /// Actual map-task count (after [`crate::mr::config::SplitPolicy`]).
    pub map_tasks: u32,
}

impl ContextShape {
    /// The shape of `(cluster, config)`.
    pub fn of(cluster: &Cluster, config: &JobConfig) -> ContextShape {
        ContextShape {
            num_nodes: cluster.num_nodes(),
            replication: config.replication,
            input_bytes: config.input_bytes,
            map_tasks: config.map_tasks(),
        }
    }
}

/// Reusable per-job setup: balanced-ingest block layout plus the split
/// plan with locality hints.  Building one costs a NameNode placement
/// pass over the whole input (~128 blocks for the paper's 8 GB); borrowing
/// it makes repetitions pay only for event simulation.
#[derive(Clone, Debug)]
pub struct JobContext {
    shape: ContextShape,
    /// The ingested input file's block layout.
    pub file: FileMeta,
    /// Planned splits with locality hints.
    pub splits: Vec<SplitPlan>,
}

impl JobContext {
    /// Plan the layout for `(cluster, config)` drawing placement decisions
    /// from `layout_rng`.
    pub fn build(
        cluster: &Cluster,
        config: &JobConfig,
        layout_rng: &mut Rng,
    ) -> JobContext {
        let shape = ContextShape::of(cluster, config);
        let mut nn = NameNode::new(shape.num_nodes, shape.replication);
        let file = nn.plan_balanced_file("/job/input", shape.input_bytes, layout_rng);
        let splits = plan_splits(&file, shape.map_tasks);
        JobContext { shape, file, splits }
    }

    /// Per-run context: the layout stream is forked from the run seed,
    /// reproducing exactly the layout `run_job` planned inline before
    /// contexts existed — standalone `run_job` stays bit-identical.
    pub fn for_job(cluster: &Cluster, config: &JobConfig) -> JobContext {
        let rng = Rng::new(config.seed ^ JOB_SEED_SALT);
        JobContext::build(cluster, config, &mut rng.fork(LAYOUT_STREAM))
    }

    /// Session context shared across repetitions: the layout depends only
    /// on the profiling session (`base_seed`) and the config shape, the
    /// way the paper's input is ingested once and profiled repeatedly.
    /// Per-rep seeds keep driving all task and run noise.
    pub fn for_session(
        cluster: &Cluster,
        config: &JobConfig,
        base_seed: u64,
    ) -> JobContext {
        let shape = ContextShape::of(cluster, config);
        // Chain the session and shape into one seed through the shared
        // SplitMix64 step (same mixer the RNG itself seeds from).  Each
        // field is folded into the previous output before remixing, so the
        // seed is position-sensitive, not a function of the field sum.
        let mut seed = base_seed ^ 0x6c61_796f_7574_3031; // "layout01"
        for v in [
            shape.num_nodes as u64,
            shape.replication as u64,
            shape.input_bytes,
            shape.map_tasks as u64,
        ] {
            let mut state = seed ^ v;
            seed = splitmix64(&mut state);
        }
        JobContext::build(cluster, config, &mut Rng::new(seed))
    }

    /// The shape this context was planned for.
    pub fn shape(&self) -> ContextShape {
        self.shape
    }

    /// Whether this context was planned for the given `(cluster, config)`
    /// shape — the reuse contract `run_job_in` enforces.
    pub fn matches(&self, cluster: &Cluster, config: &JobConfig) -> bool {
        self.shape == ContextShape::of(cluster, config)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mr::config::SplitPolicy;

    #[test]
    fn shape_ignores_sim_only_knobs() {
        let cluster = Cluster::paper_cluster();
        let a = JobConfig::paper_default(20, 5).with_seed(1);
        let mut b = JobConfig::paper_default(20, 40).with_seed(999);
        b.slowstart = 0.9;
        b.speculative = false;
        // Same hint policy + input -> same task count -> same shape.
        assert_eq!(ContextShape::of(&cluster, &a), ContextShape::of(&cluster, &b));
        let ctx = JobContext::for_session(&cluster, &a, 7);
        assert!(ctx.matches(&cluster, &b));
    }

    #[test]
    fn shape_tracks_task_count_and_input() {
        let cluster = Cluster::paper_cluster();
        let a = JobConfig::paper_default(20, 5);
        let direct = a.clone().with_split_policy(SplitPolicy::Direct);
        assert_ne!(
            ContextShape::of(&cluster, &a),
            ContextShape::of(&cluster, &direct)
        );
        let mut small = a.clone();
        small.input_bytes /= 2;
        assert!(!JobContext::for_session(&cluster, &a, 7).matches(&cluster, &small));
    }

    #[test]
    fn session_context_is_deterministic_and_rep_independent() {
        let cluster = Cluster::paper_cluster();
        let config = JobConfig::paper_default(20, 5).with_seed(123);
        let a = JobContext::for_session(&cluster, &config, 42);
        // A different run seed must not perturb the session layout.
        let b = JobContext::for_session(&cluster, &config.clone().with_seed(456), 42);
        assert_eq!(a.splits.len(), b.splits.len());
        for (x, y) in a.splits.iter().zip(&b.splits) {
            assert_eq!(x.offset, y.offset);
            assert_eq!(x.len, y.len);
            assert_eq!(x.preferred, y.preferred);
        }
        // A different session seed yields a different placement.
        let c = JobContext::for_session(&cluster, &config, 43);
        assert!(
            a.splits.iter().zip(&c.splits).any(|(x, y)| x.preferred != y.preferred),
            "distinct sessions should not share a layout"
        );
    }

    #[test]
    fn for_job_layout_pins_the_historical_stream() {
        // `run_job`'s bit-compatibility claim rests on this exact
        // derivation (the salt and fork stream the old inline planning
        // used).  The literals are repeated here on purpose: a change to
        // JOB_SEED_SALT / LAYOUT_STREAM or to for_job's internals must
        // fail this test, not silently shift every simulated time.
        let cluster = Cluster::paper_cluster();
        let config = JobConfig::paper_default(20, 5).with_seed(77);
        let rng = Rng::new(config.seed ^ 0x6a6f_625f_7275_6e73);
        let expect = JobContext::build(&cluster, &config, &mut rng.fork(1));
        let got = JobContext::for_job(&cluster, &config);
        assert_eq!(expect.splits.len(), got.splits.len());
        for (a, b) in expect.splits.iter().zip(&got.splits) {
            assert_eq!(a.preferred, b.preferred);
        }
    }

    #[test]
    fn splits_tile_the_configured_input() {
        let cluster = Cluster::paper_cluster();
        let config = JobConfig::paper_default(17, 9);
        let ctx = JobContext::for_job(&cluster, &config);
        assert_eq!(ctx.splits.len(), config.map_tasks() as usize);
        let total: u64 = ctx.splits.iter().map(|s| s.len).sum();
        assert_eq!(total, config.input_bytes);
        assert_eq!(ctx.shape().map_tasks, config.map_tasks());
    }
}
