//! Deterministic fault injection for the profiling pipeline.
//!
//! The `MRTUNER_FAIL_SPEC` environment variable poisons specific
//! repetitions inside [`super::run_job_in`] *without touching the
//! simulator's logic*, so every retry / quarantine / resume path in the
//! executor and its test harness is exercised bit-deterministically:
//!
//! ```text
//! MRTUNER_FAIL_SPEC="app=grep,m=16,r=4,rep=2,mode=panic"
//! MRTUNER_FAIL_SPEC="app=wordcount,mode=slow=150;app=grep,rep=0,mode=panic"
//! ```
//!
//! A spec is a comma-separated list of `key=value` matchers plus one
//! `mode`; several specs are separated by `;`.  Every matcher given must
//! hold for the spec to fire:
//!
//! * `app=NAME` — application name (`wordcount` / `exim` / `grep`);
//! * `m=N` / `r=N` — the job's `num_mappers` / `num_reducers`;
//! * `rep=N` — the repetition index.  The rep is executor-side context
//!   (the simulator never sees it), so the executor publishes it via
//!   [`rep_scope`]; a `rep=` matcher can only fire under such a scope.
//! * `mode=panic` — the rep panics (drives the executor's
//!   `catch_unwind` isolation, retry policy and dead-letter queue);
//! * `mode=slow` / `mode=slow=MS` — the rep sleeps `MS` wall-clock
//!   milliseconds (default 100) *before* simulating.  Simulation output
//!   is unchanged — this stretches real time so crash tests can SIGKILL
//!   a campaign mid-run deterministically.
//!
//! The variable is read once per process and cached; a malformed spec is
//! reported to stderr and ignored (the hook must never take down a
//! production run on a typo — the tests that rely on injection assert
//! its observable effects and fail loudly if the spec did not parse).

use std::cell::Cell;
use std::sync::OnceLock;

/// What a matching spec does to the repetition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailMode {
    /// Panic inside the simulator call (isolated by the executor).
    Panic,
    /// Sleep this many wall-clock milliseconds, then simulate normally.
    Slow(u64),
}

/// One parsed `MRTUNER_FAIL_SPEC` entry: the matchers plus the mode.
/// Absent matchers match everything.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FailSpec {
    /// Application name to match (`None` matches any app).
    pub app: Option<String>,
    /// `num_mappers` to match.
    pub mappers: Option<u32>,
    /// `num_reducers` to match.
    pub reducers: Option<u32>,
    /// Repetition index to match (requires an executor [`rep_scope`]).
    pub rep: Option<u32>,
    /// What to do when every matcher holds.
    pub mode: FailMode,
}

impl FailSpec {
    /// Whether this spec fires for a job of `(app, mappers, reducers)`
    /// under repetition scope `rep` (`None` when the caller is not a
    /// rep-aware driver — a `rep=` matcher then never fires).
    pub fn matches(
        &self,
        app: &str,
        mappers: u32,
        reducers: u32,
        rep: Option<u32>,
    ) -> bool {
        self.app.as_deref().is_none_or(|a| a == app)
            && self.mappers.is_none_or(|m| m == mappers)
            && self.reducers.is_none_or(|r| r == reducers)
            && self.rep.is_none_or(|want| rep == Some(want))
    }
}

/// Default sleep for `mode=slow` without an explicit duration.
const DEFAULT_SLOW_MS: u64 = 100;

/// Parse one or more `;`-separated fail specs.  Empty input is an empty
/// list; a spec without a `mode` (or with an unknown key) is an error.
pub fn parse_fail_specs(s: &str) -> Result<Vec<FailSpec>, String> {
    let mut out = Vec::new();
    for part in s.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let mut spec = FailSpec {
            app: None,
            mappers: None,
            reducers: None,
            rep: None,
            mode: FailMode::Panic,
        };
        let mut mode_seen = false;
        for field in part.split(',') {
            let field = field.trim();
            let (k, v) = field
                .split_once('=')
                .ok_or_else(|| format!("fail spec: '{field}' is not key=value"))?;
            let int = |v: &str| -> Result<u32, String> {
                v.parse().map_err(|_| format!("fail spec: {k}: bad integer '{v}'"))
            };
            match k {
                "app" => spec.app = Some(v.to_string()),
                "m" => spec.mappers = Some(int(v)?),
                "r" => spec.reducers = Some(int(v)?),
                "rep" => spec.rep = Some(int(v)?),
                "mode" => {
                    mode_seen = true;
                    spec.mode = match v.split_once('=') {
                        None if v == "panic" => FailMode::Panic,
                        None if v == "slow" => FailMode::Slow(DEFAULT_SLOW_MS),
                        Some(("slow", ms)) => FailMode::Slow(
                            ms.parse().map_err(|_| {
                                format!("fail spec: mode=slow: bad ms '{ms}'")
                            })?,
                        ),
                        _ => {
                            return Err(format!(
                                "fail spec: unknown mode '{v}' (panic | slow[=MS])"
                            ))
                        }
                    };
                }
                other => {
                    return Err(format!(
                        "fail spec: unknown key '{other}' (app | m | r | rep | mode)"
                    ))
                }
            }
        }
        if !mode_seen {
            return Err(format!("fail spec '{part}': missing mode=panic|slow"));
        }
        out.push(spec);
    }
    Ok(out)
}

/// The process-wide injected specs: `MRTUNER_FAIL_SPEC`, parsed once.
fn env_specs() -> &'static [FailSpec] {
    static SPECS: OnceLock<Vec<FailSpec>> = OnceLock::new();
    SPECS.get_or_init(|| match std::env::var("MRTUNER_FAIL_SPEC") {
        Ok(s) => match parse_fail_specs(&s) {
            Ok(specs) => specs,
            Err(e) => {
                eprintln!("warn: ignoring MRTUNER_FAIL_SPEC: {e}");
                Vec::new()
            }
        },
        Err(_) => Vec::new(),
    })
}

thread_local! {
    /// The repetition index the current thread is simulating, if a
    /// rep-aware driver (the executor) published one.
    static CURRENT_REP: Cell<Option<u32>> = const { Cell::new(None) };
}

/// RAII guard restoring the previous repetition scope on drop — drop
/// runs during unwinding too, so a panicking (injected) rep never leaks
/// its scope onto the worker thread's next job.
pub struct RepScope {
    prev: Option<u32>,
}

impl Drop for RepScope {
    fn drop(&mut self) {
        CURRENT_REP.with(|c| c.set(self.prev));
    }
}

/// Publish the repetition index for fault matching on this thread until
/// the returned guard drops.  Scopes nest; the innermost wins.
pub fn rep_scope(rep: u32) -> RepScope {
    let prev = CURRENT_REP.with(|c| c.replace(Some(rep)));
    RepScope { prev }
}

/// The repetition index published on this thread, if any.
pub fn current_rep() -> Option<u32> {
    CURRENT_REP.with(|c| c.get())
}

/// The injection hook [`super::run_job_in`] calls once per simulation,
/// after config validation and before any simulator state is built.  A
/// no-op unless `MRTUNER_FAIL_SPEC` matches this `(app, M, R, rep)`.
pub fn maybe_inject(app: &str, mappers: u32, reducers: u32) {
    let specs = env_specs();
    if specs.is_empty() {
        return;
    }
    let rep = current_rep();
    for spec in specs {
        if spec.matches(app, mappers, reducers, rep) {
            match spec.mode {
                FailMode::Panic => panic!(
                    "injected fault (MRTUNER_FAIL_SPEC): app={app} m={mappers} \
                     r={reducers} rep={rep:?}"
                ),
                FailMode::Slow(ms) => {
                    std::thread::sleep(std::time::Duration::from_millis(ms))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let specs =
            parse_fail_specs("app=grep,m=16,r=4,rep=2,mode=panic").unwrap();
        assert_eq!(specs.len(), 1);
        let s = &specs[0];
        assert_eq!(s.app.as_deref(), Some("grep"));
        assert_eq!(s.mappers, Some(16));
        assert_eq!(s.reducers, Some(4));
        assert_eq!(s.rep, Some(2));
        assert_eq!(s.mode, FailMode::Panic);
    }

    #[test]
    fn parses_multiple_and_slow_modes() {
        let specs = parse_fail_specs(
            "app=wordcount,mode=slow; app=grep,rep=0,mode=slow=250",
        )
        .unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].mode, FailMode::Slow(DEFAULT_SLOW_MS));
        assert_eq!(specs[1].mode, FailMode::Slow(250));
        assert!(parse_fail_specs("").unwrap().is_empty());
        assert!(parse_fail_specs(" ; ").unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(parse_fail_specs("app=grep").is_err(), "missing mode");
        assert!(parse_fail_specs("mode=explode").is_err(), "unknown mode");
        assert!(parse_fail_specs("mode=slow=abc").is_err(), "bad ms");
        assert!(parse_fail_specs("banana=1,mode=panic").is_err(), "bad key");
        assert!(parse_fail_specs("m=abc,mode=panic").is_err(), "bad int");
        assert!(parse_fail_specs("apppanic").is_err(), "not key=value");
    }

    #[test]
    fn matching_honors_every_field() {
        let s = &parse_fail_specs("app=grep,m=16,rep=2,mode=panic").unwrap()[0];
        assert!(s.matches("grep", 16, 4, Some(2)));
        assert!(s.matches("grep", 16, 99, Some(2)), "unset r matches any");
        assert!(!s.matches("wordcount", 16, 4, Some(2)), "wrong app");
        assert!(!s.matches("grep", 17, 4, Some(2)), "wrong m");
        assert!(!s.matches("grep", 16, 4, Some(3)), "wrong rep");
        assert!(!s.matches("grep", 16, 4, None), "rep matcher needs a scope");
        let any = &parse_fail_specs("mode=panic").unwrap()[0];
        assert!(any.matches("exim", 1, 1, None), "empty matchers match all");
    }

    #[test]
    fn rep_scope_nests_and_restores() {
        assert_eq!(current_rep(), None);
        {
            let _a = rep_scope(1);
            assert_eq!(current_rep(), Some(1));
            {
                let _b = rep_scope(7);
                assert_eq!(current_rep(), Some(7));
            }
            assert_eq!(current_rep(), Some(1), "inner scope restored");
        }
        assert_eq!(current_rep(), None, "outer scope restored");
    }

    #[test]
    fn rep_scope_survives_panic_unwind() {
        let _outer = rep_scope(3);
        let caught = std::panic::catch_unwind(|| {
            let _inner = rep_scope(9);
            panic!("boom");
        });
        assert!(caught.is_err());
        assert_eq!(current_rep(), Some(3), "unwind restored the scope");
    }
}
