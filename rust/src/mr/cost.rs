//! Task-level cost model.
//!
//! Translates (bytes, node spec, app profile, config) into task durations.
//! Constants are 2011-commodity-hardware figures; each app's CPU
//! coefficients can be *calibrated* from functional execution
//! (see `crate::apps::profiles::calibrate`), keeping the model honest.

use crate::cluster::{Network, NodeSpec};

/// Per-application cost coefficients.  CPU work is expressed in
/// nanoseconds per byte *at 1 GHz*, so node clock differences fall out as
/// `ns_per_byte / cpu_ghz` — the paper's heterogeneity axis.
#[derive(Clone, Debug)]
pub struct AppProfile {
    /// Application name (matches [`crate::apps::AppId::name`]).
    pub name: String,
    /// Map-function CPU cost per input byte (tokenize/parse/emit).
    pub map_cpu_ns_per_byte: f64,
    /// Reduce-function CPU cost per shuffled byte.
    pub reduce_cpu_ns_per_byte: f64,
    /// Shuffle bytes per input byte (post-combiner map-output selectivity).
    pub selectivity: f64,
    /// Final output bytes per input byte.
    pub output_ratio: f64,
    /// True for Hadoop-streaming jobs (mapper/reducer in Python): adds
    /// per-byte pipe cost, slower task startup and extra run-to-run noise —
    /// the effect the paper blames for Exim's larger prediction error.
    pub streaming: bool,
    /// Lognormal sigma for per-task duration noise ("temporal changes").
    pub noise_sigma: f64,
    /// Lognormal sigma for whole-run noise: background daemons / system
    /// load during that execution (the paper's §V.B explanation for
    /// prediction error, amplified for streaming jobs whose extra
    /// processes contend for the lone CPU).
    pub job_sigma: f64,
}

impl AppProfile {
    /// Effective CPU ns/byte including the streaming pipe penalty.
    fn eff_map_ns(&self) -> f64 {
        self.map_cpu_ns_per_byte + if self.streaming { STREAMING_PIPE_NS_PER_BYTE } else { 0.0 }
    }

    fn eff_reduce_ns(&self) -> f64 {
        self.reduce_cpu_ns_per_byte
            + if self.streaming { STREAMING_PIPE_NS_PER_BYTE } else { 0.0 }
    }

    /// Per-task run-to-run noise sigma (streaming doubles it, §V.B).
    pub fn task_sigma(&self) -> f64 {
        if self.streaming {
            self.noise_sigma * 2.0
        } else {
            self.noise_sigma
        }
    }

    /// Whole-run noise sigma (streaming doubles it, §V.B).
    pub fn run_sigma(&self) -> f64 {
        if self.streaming {
            self.job_sigma * 2.0
        } else {
            self.job_sigma
        }
    }
}

// ------------------------------------------------------------ constants

/// JVM spawn per task attempt (Hadoop 0.20 launched a fresh JVM unless
/// reuse was configured; the paper-era default is no reuse).
pub const TASK_STARTUP_S: f64 = 3.0;
/// Mean TaskTracker heartbeat interval: task assignment in 0.20 happens on
/// heartbeats, so every launch waits U(0, 2·mean) for its tracker to call
/// in.  This is the per-task overhead that penalizes large mapper counts.
pub const HEARTBEAT_MEAN_S: f64 = 1.5;
/// Per-reduce-task output commit: rename + NameNode metadata round trips.
pub const REDUCE_COMMIT_S: f64 = 1.2;
/// Extra startup for streaming tasks (fork Python interpreter + pipes).
pub const STREAMING_STARTUP_S: f64 = 0.9;
/// Per-byte cost of pushing records through the streaming stdin/stdout
/// pipe at 1 GHz.
pub const STREAMING_PIPE_NS_PER_BYTE: f64 = 35.0;
/// Sort CPU cost per map-output byte at 1 GHz (quicksort + serialization).
pub const SORT_NS_PER_BYTE: f64 = 28.0;
/// Merge CPU cost per byte per merge pass at 1 GHz.
pub const MERGE_NS_PER_BYTE: f64 = 12.0;
/// Job-level setup/teardown (submit, split computation, commit).
pub const JOB_OVERHEAD_S: f64 = 6.0;

/// Map-side costs for one split on one node.
#[derive(Clone, Copy, Debug)]
pub struct MapCost {
    /// JVM/task-launch overhead.
    pub startup_s: f64,
    /// Input read time (local disk or network).
    pub read_s: f64,
    /// Map-function CPU time.
    pub cpu_s: f64,
    /// Sort + spill + extra-merge time.
    pub spill_s: f64,
    /// Number of spill passes.
    pub spills: u32,
    /// Map-output bytes produced.
    pub out_bytes: u64,
}

impl MapCost {
    /// Total map-task service time.
    pub fn total_s(&self) -> f64 {
        self.startup_s + self.read_s + self.cpu_s + self.spill_s
    }
}

/// Compute map-task cost for `split_bytes` of input on `node`.
///
/// `local` is the HDFS locality decision from the scheduler; remote reads
/// pay the network instead of (most of) the local disk.
pub fn map_cost(
    app: &AppProfile,
    node: &NodeSpec,
    net: &Network,
    split_bytes: u64,
    local: bool,
) -> MapCost {
    let ghz = node.speed();
    let startup_s =
        TASK_STARTUP_S + if app.streaming { STREAMING_STARTUP_S } else { 0.0 };

    // Input: local disk scan or remote fetch (remote also writes through
    // the local page cache; dominated by the slower of net and disk).
    let read_s = if local {
        split_bytes as f64 / (node.disk_read_mbps * 1e6)
    } else {
        let net_s = net.transfer_secs(split_bytes, 2, 2); // typical contention
        let disk_s = split_bytes as f64 / (node.disk_read_mbps * 1e6);
        net_s.max(disk_s)
    };

    let cpu_s =
        split_bytes as f64 * app.eff_map_ns() * 1e-9 / ghz * node.cache_penalty();

    // Map-output sort & spill: output beyond the in-JVM sort buffer spills
    // to disk in passes; more than `merge_factor` spill files would add
    // intermediate merges, approximated by one extra pass per overflow.
    let out_bytes = (split_bytes as f64 * app.selectivity) as u64;
    let buffer = node.sort_buffer_bytes();
    let spills = (out_bytes + buffer - 1) / buffer.max(1);
    let spills = spills.max(1) as u32;
    let sort_cpu_s = out_bytes as f64 * SORT_NS_PER_BYTE * 1e-9 / ghz
        * node.cache_penalty();
    let spill_io_s = out_bytes as f64 / (node.disk_write_mbps * 1e6);
    // Multi-spill maps re-read + merge the bytes that overflowed the
    // buffer at task end.  Cost scales with the *excess* bytes (continuous
    // in split size) rather than jumping at integer spill counts — on real
    // hardware the page cache and combiner smear this boundary out.
    let excess = out_bytes.saturating_sub(buffer) as f64;
    let merge_extra_s = if excess > 0.0 {
        (excess + out_bytes as f64).min(2.0 * excess) / (node.disk_read_mbps * 1e6)
            + excess * MERGE_NS_PER_BYTE * 1e-9 / ghz
    } else {
        0.0
    };
    MapCost {
        startup_s,
        read_s,
        cpu_s,
        spill_s: sort_cpu_s + spill_io_s + merge_extra_s,
        spills,
        out_bytes,
    }
}

/// Reduce-side (post-shuffle) costs for one reducer.
#[derive(Clone, Copy, Debug)]
pub struct ReduceCost {
    /// JVM/task-launch overhead.
    pub startup_s: f64,
    /// Multi-pass merge time for the fetched map outputs.
    pub merge_s: f64,
    /// Reduce-function CPU time.
    pub cpu_s: f64,
    /// Replicated output-write time.
    pub write_s: f64,
    /// Merge passes performed.
    pub merge_passes: u32,
}

impl ReduceCost {
    /// Total reduce-task service time (excluding shuffle wait).
    pub fn total_s(&self) -> f64 {
        self.startup_s + self.merge_s + self.cpu_s + self.write_s
    }
}

/// Cost of the merge+reduce+write stages for one reducer that received
/// `volume` shuffled bytes from `num_maps` map outputs.
pub fn reduce_cost(
    app: &AppProfile,
    node: &NodeSpec,
    net: &Network,
    volume: u64,
    num_maps: u32,
    merge_factor: u32,
    replication: usize,
) -> ReduceCost {
    let ghz = node.speed();
    let startup_s =
        TASK_STARTUP_S + if app.streaming { STREAMING_STARTUP_S } else { 0.0 };

    // Multi-pass merge of `num_maps` segments with fan-in `merge_factor`.
    // The integer pass count is kept for counters, but the *cost* uses the
    // continuous pass equivalent log_factor(segments/factor): Hadoop's
    // merger only re-reads the subset of segments merged in intermediate
    // rounds, so effective IO grows smoothly, not in cliff steps.
    let segments = num_maps.max(1);
    let merge_passes = {
        let mut s = segments;
        let mut p = 0u32;
        while s > merge_factor {
            s = s.div_ceil(merge_factor);
            p += 1;
        }
        p
    };
    let passes_f = if segments > merge_factor {
        (segments as f64 / merge_factor as f64).ln() / (merge_factor as f64).ln()
    } else {
        0.0
    };
    // Every effective pass reads + writes the volume; the final in-memory
    // merge feeding the reducer costs CPU only.
    let pass_io_s = volume as f64
        * (1.0 / (node.disk_read_mbps * 1e6) + 1.0 / (node.disk_write_mbps * 1e6));
    let pass_cpu_s =
        volume as f64 * MERGE_NS_PER_BYTE * 1e-9 / ghz * node.cache_penalty();
    let merge_s = passes_f * (pass_io_s + pass_cpu_s) + pass_cpu_s;

    let cpu_s = volume as f64 * app.eff_reduce_ns() * 1e-9 / ghz
        * node.cache_penalty();

    // Output commit: local write plus (replication-1) pipeline copies over
    // the network; HDFS pipelining overlaps them, so cost is the max of
    // local disk and the slowest network hop.
    let out_bytes = (volume as f64 * app.output_ratio / app.selectivity.max(1e-9)) as u64;
    let disk_s = out_bytes as f64 / (node.disk_write_mbps * 1e6);
    let extra = replication.saturating_sub(1) as u32;
    let net_s = if extra > 0 {
        net.transfer_secs(out_bytes, 2, 2)
    } else {
        0.0
    };
    ReduceCost {
        startup_s,
        merge_s,
        cpu_s,
        write_s: disk_s.max(net_s) + REDUCE_COMMIT_S,
        merge_passes,
    }
}

/// Synthetic profile for framework tests (not a real application).
#[cfg(test)]
pub(crate) fn test_profile(streaming: bool) -> AppProfile {
    AppProfile {
        name: "test".into(),
        map_cpu_ns_per_byte: 150.0,
        reduce_cpu_ns_per_byte: 40.0,
        selectivity: 0.3,
        output_ratio: 0.2,
        streaming,
        noise_sigma: 0.03,
        job_sigma: 0.01,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Cluster;

    fn setup() -> (Cluster, AppProfile) {
        (Cluster::paper_cluster(), test_profile(false))
    }

    #[test]
    fn map_cost_scales_with_split_size() {
        let (c, app) = setup();
        let small = map_cost(&app, &c.nodes[0].spec, &c.network, 100 << 20, true);
        let large = map_cost(&app, &c.nodes[0].spec, &c.network, 400 << 20, true);
        assert!(large.total_s() > 3.0 * small.total_s());
        // Startup does not scale.
        assert_eq!(small.startup_s, large.startup_s);
    }

    #[test]
    fn fast_node_beats_slow_node_on_cpu() {
        let (c, app) = setup();
        let fast = map_cost(&app, &c.nodes[0].spec, &c.network, 256 << 20, true);
        let slow = map_cost(&app, &c.nodes[2].spec, &c.network, 256 << 20, true);
        assert!(slow.cpu_s > fast.cpu_s);
        // 2.9/2.5 clock ratio plus cache penalty.
        let ratio = slow.cpu_s / fast.cpu_s;
        assert!(ratio > 1.1 && ratio < 1.35, "ratio {ratio}");
    }

    #[test]
    fn remote_read_slower_than_local() {
        let (c, app) = setup();
        let local = map_cost(&app, &c.nodes[0].spec, &c.network, 256 << 20, true);
        let remote = map_cost(&app, &c.nodes[0].spec, &c.network, 256 << 20, false);
        assert!(remote.read_s >= local.read_s);
        assert_eq!(remote.cpu_s, local.cpu_s);
    }

    #[test]
    fn big_splits_spill_more() {
        let (c, app) = setup();
        // Slow node has a smaller sort buffer -> spills earlier.
        let spec = &c.nodes[2].spec;
        let small = map_cost(&app, spec, &c.network, 64 << 20, true);
        let big = map_cost(&app, spec, &c.network, 1 << 30, true);
        assert_eq!(small.spills, 1);
        assert!(big.spills > 1, "1 GB split must spill (got {})", big.spills);
        assert!(big.spill_s > small.spill_s);
    }

    #[test]
    fn streaming_adds_startup_and_cpu() {
        let (c, _) = setup();
        let plain = map_cost(&test_profile(false), &c.nodes[0].spec, &c.network, 256 << 20, true);
        let stream = map_cost(&test_profile(true), &c.nodes[0].spec, &c.network, 256 << 20, true);
        assert!(stream.startup_s > plain.startup_s);
        assert!(stream.cpu_s > plain.cpu_s);
    }

    #[test]
    fn streaming_doubles_noise() {
        assert_eq!(test_profile(true).task_sigma(), 2.0 * test_profile(false).task_sigma());
    }

    #[test]
    fn merge_passes_follow_fanin() {
        let (c, app) = setup();
        let spec = &c.nodes[0].spec;
        let few = reduce_cost(&app, spec, &c.network, 100 << 20, 8, 10, 3);
        let many = reduce_cost(&app, spec, &c.network, 100 << 20, 40, 10, 3);
        assert_eq!(few.merge_passes, 0); // 8 segments <= factor 10
        assert_eq!(many.merge_passes, 1); // 40 -> 4 segments
        assert!(many.merge_s > few.merge_s);
    }

    #[test]
    fn replication_write_costs_network() {
        let (c, app) = setup();
        let spec = &c.nodes[0].spec;
        let r1 = reduce_cost(&app, spec, &c.network, 200 << 20, 10, 10, 1);
        let r3 = reduce_cost(&app, spec, &c.network, 200 << 20, 10, 10, 3);
        assert!(r3.write_s >= r1.write_s);
    }

    #[test]
    fn totals_are_positive_and_finite() {
        let (c, app) = setup();
        for node in &c.nodes {
            let m = map_cost(&app, &node.spec, &c.network, 8 << 30, false);
            let r = reduce_cost(&app, &node.spec, &c.network, 1 << 30, 40, 10, 3);
            assert!(m.total_s().is_finite() && m.total_s() > 0.0);
            assert!(r.total_s().is_finite() && r.total_s() > 0.0);
        }
    }
}
