//! Bench: regenerate **Fig. 4** (a,b = WordCount; c,d = Exim) — the total
//! execution time surface over (num_mappers, num_reducers), and check the
//! paper's qualitative observations:
//!
//! * WordCount runs roughly double Exim's time (§V.B);
//! * both surfaces are minimal at low reducer counts / mid mapper hints
//!   (the paper reports (20, 5) and admits "the reason ... is not clear");
//! * WordCount's prediction-relevant structure is smoother than Exim's
//!   noise (driving Table 1's error ordering).
//!
//! Run: `cargo bench --bench fig4_surface`

use std::time::Instant;

use mrtuner::apps::AppId;
use mrtuner::profiler::CampaignExecutor;
use mrtuner::report::experiments::{fig4, fig4_with};
use mrtuner::report::figure;
use mrtuner::util::benchkit::{bench, report, section};

fn main() {
    let mut means = Vec::new();
    for app in AppId::paper_apps() {
        section(&format!("Fig. 4 — {}", app.name()));
        let d = fig4(app, 5, 5, 42);
        print!(
            "{}",
            figure::surface(
                &format!("total execution time (s), {}", app.name()),
                &d.ms,
                &d.rs,
                &d.times,
            )
        );
        let (bm, br) = d.argmin();
        report(
            &format!("{} surface minimum (paper: M=20, R=5)", app.name()),
            format!("M={bm}, R={br}"),
        );
        report(
            &format!("{} fluctuation (max-min)/min", app.name()),
            format!("{:.3}", d.fluctuation()),
        );
        report(
            &format!("{} mean over grid", app.name()),
            format!("{:.1} s", d.mean_time()),
        );
        means.push(d.mean_time());
    }

    section("cross-application shape checks");
    let ratio = means[0] / means[1];
    report(
        "wordcount / exim mean-time ratio (paper: ~2x)",
        format!("{ratio:.2}"),
    );
    report(
        "wordcount slower than exim",
        if ratio > 1.3 { "yes" } else { "NO" },
    );

    section("sweep cost");
    bench("fig4 lattice sweep (64 settings x 1 rep)", 1, 3, || {
        std::hint::black_box(fig4(AppId::EximParse, 5, 1, 7));
    });

    // ------------------------------------------- parallel executor scaling
    // The acceptance bar for the campaign executor: a parallel Fig-4 grid
    // sweep must be bit-identical to the serial sweep and >= 2x faster on
    // a multi-core host.  Fresh executors per run keep the cache cold so
    // the timings measure simulation, not lookups.
    section("campaign executor scaling (Fig. 4 grid, 64 settings x 3 reps)");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let time_sweep = |jobs: usize| -> (f64, Vec<f64>) {
        let exec = CampaignExecutor::new(jobs);
        let t0 = Instant::now();
        let d = fig4_with(&exec, AppId::WordCount, 5, 3, 42);
        (t0.elapsed().as_secs_f64(), d.times)
    };
    let (serial_s, serial_times) = time_sweep(1);
    report("serial sweep (jobs=1)", format!("{serial_s:.3} s"));
    let mut counts: Vec<usize> = [2, 4, cores].into_iter().filter(|&j| j > 1).collect();
    counts.sort_unstable();
    counts.dedup();
    for jobs in counts {
        let (par_s, par_times) = time_sweep(jobs);
        let identical = par_times == serial_times;
        report(
            &format!("parallel sweep (jobs={jobs})"),
            format!(
                "{par_s:.3} s  speedup {:.2}x  bit-identical: {}",
                serial_s / par_s,
                if identical { "yes" } else { "NO — DETERMINISM BUG" }
            ),
        );
        assert!(identical, "parallel sweep diverged from serial");
    }
    report(
        &format!("host cores = {cores}; >= 2x target"),
        if cores >= 4 {
            "expect speedup >= 2x at jobs=cores"
        } else {
            "host too small to show 2x; run on a multi-core box"
        },
    );

    // Cache: re-sweeping the same session is pure lookup.
    let exec = CampaignExecutor::new(cores);
    let t0 = Instant::now();
    std::hint::black_box(fig4_with(&exec, AppId::WordCount, 5, 3, 42));
    let cold = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    std::hint::black_box(fig4_with(&exec, AppId::WordCount, 5, 3, 42));
    let warm = t0.elapsed().as_secs_f64();
    report(
        "cached re-sweep",
        format!(
            "{:.1} us (cold {cold:.3} s, {} hits)",
            warm * 1e6,
            exec.cache_hits()
        ),
    );
}
