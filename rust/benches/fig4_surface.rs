//! Bench: regenerate **Fig. 4** (a,b = WordCount; c,d = Exim) — the total
//! execution time surface over (num_mappers, num_reducers), and check the
//! paper's qualitative observations:
//!
//! * WordCount runs roughly double Exim's time (§V.B);
//! * both surfaces are minimal at low reducer counts / mid mapper hints
//!   (the paper reports (20, 5) and admits "the reason ... is not clear");
//! * WordCount's prediction-relevant structure is smoother than Exim's
//!   noise (driving Table 1's error ordering).
//!
//! Run: `cargo bench --bench fig4_surface`

use mrtuner::apps::AppId;
use mrtuner::report::experiments::fig4;
use mrtuner::report::figure;
use mrtuner::util::benchkit::{bench, report, section};

fn main() {
    let mut means = Vec::new();
    for app in AppId::paper_apps() {
        section(&format!("Fig. 4 — {}", app.name()));
        let d = fig4(app, 5, 5, 42);
        print!(
            "{}",
            figure::surface(
                &format!("total execution time (s), {}", app.name()),
                &d.ms,
                &d.rs,
                &d.times,
            )
        );
        let (bm, br) = d.argmin();
        report(
            &format!("{} surface minimum (paper: M=20, R=5)", app.name()),
            format!("M={bm}, R={br}"),
        );
        report(
            &format!("{} fluctuation (max-min)/min", app.name()),
            format!("{:.3}", d.fluctuation()),
        );
        report(
            &format!("{} mean over grid", app.name()),
            format!("{:.1} s", d.mean_time()),
        );
        means.push(d.mean_time());
    }

    section("cross-application shape checks");
    let ratio = means[0] / means[1];
    report(
        "wordcount / exim mean-time ratio (paper: ~2x)",
        format!("{ratio:.2}"),
    );
    report(
        "wordcount slower than exim",
        if ratio > 1.3 { "yes" } else { "NO" },
    );

    section("sweep cost");
    bench("fig4 lattice sweep (64 settings x 1 rep)", 1, 3, || {
        std::hint::black_box(fig4(AppId::EximParse, 5, 1, 7));
    });
}
