//! Bench: regenerate **Table 1** — statistical mean and variance of
//! prediction errors for WordCount and Exim — across several independent
//! profiling sessions (seeds), reporting the spread so the comparison
//! against the paper's single numbers is honest.
//!
//! Run: `cargo bench --bench table1_errors`

use mrtuner::apps::AppId;
use mrtuner::report::experiments::table1;
use mrtuner::util::benchkit::{report, section};
use mrtuner::util::stats;

fn main() {
    const SEEDS: [u64; 5] = [42, 7, 2012, 555, 90210];
    let mut per_app: std::collections::BTreeMap<&str, (Vec<f64>, Vec<f64>)> =
        std::collections::BTreeMap::new();

    for &seed in &SEEDS {
        section(&format!("Table 1 — session seed {seed}"));
        println!(
            "{:<12} {:>10} {:>14} {:>12} {:>16}",
            "application", "mean (%)", "variance (%)", "paper mean", "paper variance"
        );
        for row in table1(seed) {
            println!(
                "{:<12} {:>10.4} {:>14.4} {:>12.4} {:>16.4}",
                row.app.name(),
                row.mean_pct,
                row.variance_pct,
                row.paper_mean_pct,
                row.paper_variance_pct
            );
            let e = per_app.entry(row.app.name()).or_default();
            e.0.push(row.mean_pct);
            e.1.push(row.variance_pct);
        }
    }

    section("across sessions");
    for (app, (m, v)) in &per_app {
        report(
            &format!("{app} mean error over {} sessions", SEEDS.len()),
            format!(
                "{:.3}% +- {:.3}  (paper {})",
                stats::mean(m),
                stats::stddev(m),
                if *app == "wordcount" { "0.9204%" } else { "2.7982%" }
            ),
        );
        report(
            &format!("{app} error variance over sessions"),
            format!(
                "{:.3}% +- {:.3}  (paper {})",
                stats::mean(v),
                stats::stddev(v),
                if *app == "wordcount" { "2.6013%" } else { "6.7008%" }
            ),
        );
    }
    let wc = stats::mean(&per_app["wordcount"].0);
    let ex = stats::mean(&per_app["exim"].0);
    report("headline: both < 5%", if wc < 5.0 && ex < 5.0 { "REPRODUCED" } else { "NO" });
    report(
        "ordering: exim error > wordcount error (paper: yes)",
        if ex > wc { "yes" } else { "NO" },
    );
    let _ = AppId::paper_apps();
}
