//! Bench: the paper's proposed extensions, implemented and quantified.
//!
//! 1. **Four configuration parameters** (companion work [24]): model
//!    T(M, R, input_size, block_size) with the generalized N-parameter
//!    cubic.
//! 2. **CPU tick clocks** ([24]'s modeled output): same pipeline, CPU
//!    seconds instead of wall time.
//! 3. **Nonlinear model** (§III: "better to use nonlinear modeling
//!    techniques like neural network"): a small MLP vs the cubic on the
//!    2-parameter problem.
//! 4. **Third application** (Grep): the per-application modeling protocol
//!    generalizes beyond the paper's two benchmarks.
//!
//! Run: `cargo bench --bench extensions`

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::model::mlp::{MlpConfig, MlpModel};
use mrtuner::model::ndpoly::NdPolyModel;
use mrtuner::profiler::extended::{random_ext4, scales};
use mrtuner::profiler::{paper_campaign, CampaignExecutor};
use mrtuner::util::benchkit::{bench, report, section};
use mrtuner::util::rng::Rng;
use mrtuner::util::stats;

fn main() {
    let cluster = Cluster::paper_cluster();
    // One machine-sized executor for every sweep below: the 4-parameter
    // campaigns fan out over all cores and overlapping settings are
    // answered from the rep cache, exactly like the 2-parameter path.
    let exec = CampaignExecutor::machine_sized();

    // ---------------------------------------- 1+2: 4-parameter modeling
    for app in [AppId::WordCount, AppId::EximParse] {
        section(&format!("extension 1+2: 4-parameter model — {}", app.name()));
        let mut rng = Rng::new(2024);
        let train_specs = random_ext4(app, 60, &mut rng);
        let test_specs = random_ext4(app, 25, &mut rng);
        let (rows, times, cpus) =
            exec.run_ext4_campaign(&cluster, &train_specs, 5, 1);
        let (trows, ttimes, tcpus) =
            exec.run_ext4_campaign(&cluster, &test_specs, 5, 2);
        let w = vec![1.0; rows.len()];

        let time_model =
            NdPolyModel::fit(app.name(), &rows, &times, &w, 3, &scales()).unwrap();
        let terr = stats::mean_abs_err_pct(&time_model.predict(&trows), &ttimes);
        report(
            &format!("{} T(M,R,input,block) held-out error", app.name()),
            format!("{terr:.3}%  ({} features, paper's additive basis)", time_model.num_features()),
        );
        // The additive Eqn.-2 basis cannot express input x block coupling
        // (task count = input / block); pairwise interactions fix it.
        let inter_model = NdPolyModel::fit_opts(
            app.name(), &rows, &times, &w, 3, &scales(), true,
        )
        .unwrap();
        let ierr = stats::mean_abs_err_pct(&inter_model.predict(&trows), &ttimes);
        report(
            &format!("{} same + pairwise interactions", app.name()),
            format!("{ierr:.3}%  ({} features)", inter_model.num_features()),
        );

        let cpu_model =
            NdPolyModel::fit(app.name(), &rows, &cpus, &w, 3, &scales()).unwrap();
        let cerr = stats::mean_abs_err_pct(&cpu_model.predict(&trows), &tcpus);
        report(
            &format!("{} CPU-seconds model held-out error ([24])", app.name()),
            format!("{cerr:.3}%"),
        );
    }

    // ------------------------------------------------- 3: MLP vs cubic
    section("extension 3: nonlinear (MLP) vs per-parameter cubic");
    let app = AppId::WordCount;
    let (train_c, test_c) = paper_campaign(app, 42);
    let (_, train) = train_c.run(&cluster);
    let (_, test) = test_c.run(&cluster);

    let pairs: Vec<[f64; 2]> = train.params.clone();
    let mlp = MlpModel::fit(
        app.name(),
        &pairs,
        &train.times,
        MlpConfig { hidden: 16, epochs: 4000, lr: 0.01, seed: 5 },
    )
    .unwrap();
    let mlp_preds: Vec<f64> = test
        .params
        .iter()
        .map(|p| mlp.predict_one(p[0] as u32, p[1] as u32))
        .collect();
    report(
        "MLP (2-16-16-1, 4000 epochs) held-out error",
        format!("{:.3}%", stats::mean_abs_err_pct(&mlp_preds, &test.times)),
    );
    let cubic = mrtuner::model::solver::fit(
        &train.params,
        &train.times,
        &vec![1.0; train.len()],
    )
    .unwrap();
    let cubic_preds: Vec<f64> = test
        .params
        .iter()
        .map(|p| mrtuner::model::features::evaluate(&cubic, p))
        .collect();
    report(
        "cubic (paper) held-out error",
        format!("{:.3}%", stats::mean_abs_err_pct(&cubic_preds, &test.times)),
    );
    bench("MLP training (20 rows, 4000 epochs)", 0, 3, || {
        std::hint::black_box(
            MlpModel::fit(
                "wc",
                &pairs,
                &train.times,
                MlpConfig { hidden: 16, epochs: 4000, lr: 0.01, seed: 5 },
            )
            .unwrap(),
        );
    });

    // ------------------------------------------ 4: third application
    section("extension 4: grep (third application)");
    let d = mrtuner::report::experiments::fig3(AppId::Grep, 42);
    report(
        "grep held-out mean error (not in paper)",
        format!("{:.3}%", d.errors.mean_pct()),
    );
    report(
        "grep error < 5% (protocol generalizes)",
        if d.errors.mean_pct() < 5.0 { "yes" } else { "NO" },
    );

    report("executor", exec.stats());
}
