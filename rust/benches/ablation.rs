//! Bench: ablations of the design choices DESIGN.md calls out.
//!
//! 1. **Polynomial degree** — the paper picks per-parameter cubics and
//!    suggests "it is better to use nonlinear modeling techniques";
//!    degree 1/2/3/4 quantifies what the cubic buys.
//! 2. **Training-set size** — 20 settings (the paper) vs fewer/more.
//! 3. **Repetition averaging** — 5 runs per setting (the paper) vs 1.
//! 4. **Split semantics** — faithful Hadoop-0.20 hint (block-bounded
//!    splits; default) vs Direct (hint = exact split count): the wave
//!    quantization cliffs under Direct are exactly what a cubic cannot
//!    fit, and the reason the faithful semantics reproduce the paper's
//!    error levels.
//!
//! Run: `cargo bench --bench ablation`

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::model::solver;
use mrtuner::mr::config::SplitPolicy;
use mrtuner::mr::{run_job, JobConfig};
use mrtuner::profiler::campaign::{random_specs, spread_specs};
use mrtuner::util::benchkit::{report, section};
use mrtuner::util::rng::Rng;
use mrtuner::util::stats;

/// Profile `specs` with an explicit split policy and rep count.
fn profile(
    cluster: &Cluster,
    app: AppId,
    specs: &[mrtuner::profiler::ExperimentSpec],
    reps: u32,
    policy: SplitPolicy,
    base_seed: u64,
) -> (Vec<[f64; 2]>, Vec<f64>) {
    let profile = app.profile();
    let mut params = Vec::new();
    let mut times = Vec::new();
    for (i, s) in specs.iter().enumerate() {
        let mut acc = 0.0;
        for rep in 0..reps {
            let seed = base_seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add((i as u64) << 8 | rep as u64);
            let config = JobConfig::paper_default(s.num_mappers, s.num_reducers)
                .with_seed(seed)
                .with_split_policy(policy);
            acc += run_job(cluster, &profile, &config).total_time_s;
        }
        params.push(s.params());
        times.push(acc / reps as f64);
    }
    (params, times)
}

/// Held-out mean absolute percent error for a degree-d fit.
fn test_error(
    train: (&[[f64; 2]], &[f64]),
    test: (&[[f64; 2]], &[f64]),
    degree: usize,
) -> f64 {
    let w = vec![1.0; train.0.len()];
    let coeffs = solver::fit_poly(train.0, train.1, &w, degree).expect("fit");
    let errs: Vec<f64> = test
        .0
        .iter()
        .zip(test.1)
        .map(|(p, &t)| 100.0 * (solver::evaluate_poly(&coeffs, p, degree) - t).abs() / t)
        .collect();
    stats::mean(&errs)
}

fn main() {
    let cluster = Cluster::paper_cluster();
    let app = AppId::WordCount;
    let hint = SplitPolicy::HadoopHint { block_bytes: 64 << 20 };

    let mut rng = Rng::new(99);
    let train_specs = spread_specs(app, 20, &mut rng);
    let test_specs = random_specs(app, 20, &mut rng);
    let (trp, trt) = profile(&cluster, app, &train_specs, 5, hint, 1);
    let (tep, tet) = profile(&cluster, app, &test_specs, 5, hint, 2);

    // ------------------------------------------------ 1. polynomial degree
    section("ablation 1: polynomial degree (paper uses 3)");
    for d in 1..=4usize {
        let err = test_error((&trp, &trt), (&tep, &tet), d);
        report(
            &format!("degree {d} held-out mean error"),
            format!("{err:.3}%"),
        );
    }

    // --------------------------------------------- 2. training-set size
    section("ablation 2: training-set size (paper uses 20)");
    for n in [5usize, 10, 20, 40] {
        let mut rng = Rng::new(1000 + n as u64);
        let specs = spread_specs(app, n, &mut rng);
        let (p, t) = profile(&cluster, app, &specs, 5, hint, 3);
        let err = test_error((&p, &t), (&tep, &tet), 3);
        report(
            &format!("{n:>2} training settings, degree 3"),
            format!("{err:.3}%"),
        );
    }

    // ------------------------------------------------- 3. rep averaging
    section("ablation 3: repetitions per setting (paper uses 5)");
    for reps in [1u32, 3, 5, 10] {
        let (p, t) = profile(&cluster, app, &train_specs, reps, hint, 4);
        let err = test_error((&p, &t), (&tep, &tet), 3);
        report(&format!("{reps:>2} reps per setting"), format!("{err:.3}%"));
    }

    // ---------------------------------------------- 4. split semantics
    section("ablation 4: mapper-hint semantics (the key modeling choice)");
    for (name, policy) in [
        ("hadoop-hint (block-bounded splits, faithful 0.20)", hint),
        ("direct (hint = exact split count)", SplitPolicy::Direct),
    ] {
        let (p, t) = profile(&cluster, app, &train_specs, 5, policy, 5);
        let (ptest, ttest) = profile(&cluster, app, &test_specs, 5, policy, 6);
        let err = test_error((&p, &t), (&ptest, &ttest), 3);
        report(
            &format!("{name} held-out error"),
            format!("{err:.3}%"),
        );
    }
    println!(
        "\nnote: under Direct semantics the slot-wave quantization produces\n\
         cliffs in T(M) that a per-parameter cubic cannot express — the\n\
         error gap above is the quantitative argument (DESIGN.md §5) for\n\
         reading the paper's mapper count as the Hadoop-0.20 hint it was."
    );
}
