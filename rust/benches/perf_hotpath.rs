//! Bench: performance of every hot path (EXPERIMENTS.md §Perf).
//!
//! * DES simulator: jobs/sec and events/sec per app;
//! * fit: PJRT artifact vs pure-Rust Cholesky;
//! * predict: batch-size scaling of the PJRT predict artifact;
//! * prediction service: request latency and batching amortization across
//!   `max_wait` settings.
//!
//! Run: `cargo bench --bench perf_hotpath`

use std::time::Duration;

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::coordinator::{ModelRegistry, PredictionService, ServiceConfig};
use mrtuner::model::features::NUM_FEATURES;
use mrtuner::model::regression::{FitBackend, RegressionModel, RustSolverBackend};
use mrtuner::mr::{run_job, run_job_in, JobConfig, JobContext};
use mrtuner::profiler::campaign::grid_specs;
use mrtuner::profiler::CampaignExecutor;
use mrtuner::runtime::{artifacts, XlaBackend};
use mrtuner::util::benchkit::{bench, report, section};
use mrtuner::util::rng::Rng;

fn training_set(n: usize, seed: u64) -> (Vec<[f64; 2]>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let params: Vec<[f64; 2]> = (0..n)
        .map(|_| [rng.range_u64(5, 41) as f64, rng.range_u64(5, 41) as f64])
        .collect();
    let times: Vec<f64> = params
        .iter()
        .map(|p| 400.0 + 3.0 * p[0] + 2.0 * p[1] * rng.lognormal(0.05))
        .collect();
    (params, times, vec![1.0; n])
}

fn main() {
    // ---------------------------------------------------------- simulator
    section("L3 simulator");
    let cluster = Cluster::paper_cluster();
    for app in AppId::all() {
        let profile = app.profile();
        let mut seed = 0u64;
        let st = bench(&format!("run_job {} (128 maps, R=5)", app.name()), 2, 30, || {
            let config = JobConfig::paper_default(20, 5).with_seed({
                seed += 1;
                seed
            });
            std::hint::black_box(run_job(&cluster, &profile, &config));
        });
        let config = JobConfig::paper_default(20, 5).with_seed(1);
        let res = run_job(&cluster, &profile, &config);
        let tasks = (res.maps.len() + res.reduces.len()) as f64;
        report(
            &format!("{} simulated tasks/sec", app.name()),
            format!("{:.0}", st.throughput(tasks)),
        );
    }
    let mut seed = 0;
    bench("run_job wordcount (R=40, reduce waves)", 2, 30, || {
        let config = JobConfig::paper_default(40, 40).with_seed({
            seed += 1;
            seed
        });
        std::hint::black_box(run_job(&cluster, &AppId::WordCount.profile(), &config));
    });
    // JobContext reuse: the same job without per-run layout planning.
    {
        let profile = AppId::WordCount.profile();
        let base = JobConfig::paper_default(20, 5);
        let ctx = JobContext::for_session(&cluster, &base, 1);
        let mut seed = 0u64;
        bench("run_job_in wordcount (shared JobContext)", 2, 30, || {
            seed += 1;
            let config = base.clone().with_seed(seed);
            std::hint::black_box(run_job_in(&cluster, &profile, &config, &ctx));
        });
    }

    // -------------------------------------------------- campaign executor
    section("campaign executor (Fig. 4 grid, 64 settings x 1 rep)");
    let specs = grid_specs(AppId::WordCount, 5);
    for jobs in [1usize, 2, 4, 8] {
        bench(&format!("grid sweep, jobs={jobs}"), 0, 3, || {
            // Fresh executor per iteration: cold cache, measure simulation.
            let exec = CampaignExecutor::new(jobs);
            std::hint::black_box(exec.run_specs(&cluster, &specs, 1, 7));
        });
    }
    {
        let exec = CampaignExecutor::machine_sized();
        exec.run_specs(&cluster, &specs, 1, 7); // warm the cache
        let st = bench("grid sweep, warm cache", 1, 10, || {
            std::hint::black_box(exec.run_specs(&cluster, &specs, 1, 7));
        });
        report(
            "cached settings/sec",
            format!("{:.0}  ({} hits recorded)", st.throughput(specs.len() as f64), exec.cache_hits()),
        );
    }

    // ------------------------------------------------------------- fitting
    section("fit backends (paper Eqn. 6)");
    let (params, times, weights) = training_set(20, 1);
    let mut rust = RustSolverBackend;
    bench("fit 20 rows, rust-cholesky", 5, 200, || {
        std::hint::black_box(rust.fit(&params, &times, &weights).unwrap());
    });
    let have_artifacts = artifacts::default_dir().join("manifest.json").exists();
    if have_artifacts {
        let mut xla = XlaBackend::load_default().expect("artifacts");
        bench("fit 20 rows, xla-pjrt artifact", 5, 200, || {
            std::hint::black_box(xla.fit(&params, &times, &weights).unwrap());
        });
        let (p64, t64, w64) = training_set(64, 2);
        bench("fit 64 rows (full artifact), xla-pjrt", 5, 200, || {
            std::hint::black_box(xla.fit(&p64, &t64, &w64).unwrap());
        });
    } else {
        println!("(artifacts not built; skipping PJRT fit benches)");
    }

    // ----------------------------------------------------------- predicting
    section("predict batch scaling");
    let coeffs: [f64; NUM_FEATURES] = [400.0, 80.0, -20.0, 5.0, 60.0, -10.0, 2.0];
    for batch in [1usize, 8, 64, 256] {
        let (p, _, _) = training_set(batch, 3);
        let mut rust = RustSolverBackend;
        let st = bench(&format!("predict {batch:>3} rows, rust"), 5, 200, || {
            std::hint::black_box(rust.predict(&coeffs, &p).unwrap());
        });
        report(
            &format!("rust predictions/sec at batch {batch}"),
            format!("{:.0}", st.throughput(batch as f64)),
        );
    }
    if have_artifacts {
        let mut xla = XlaBackend::load_default().expect("artifacts");
        for batch in [1usize, 8, 64, 256] {
            let (p, _, _) = training_set(batch, 3);
            let st = bench(&format!("predict {batch:>3} rows, xla-pjrt"), 5, 100, || {
                std::hint::black_box(xla.predict(&coeffs, &p).unwrap());
            });
            report(
                &format!("pjrt predictions/sec at batch {batch}"),
                format!("{:.0}", st.throughput(batch as f64)),
            );
        }
    }

    // ------------------------------------------------------------- service
    section("prediction service (batching coordinator)");
    let model = RegressionModel {
        app_name: "wordcount".into(),
        coeffs,
        trained_on: 20,
    };
    for wait_us in [0u64, 200, 500, 2000] {
        let mut reg = ModelRegistry::new();
        reg.insert(model.clone());
        let svc = PredictionService::start(
            || Box::new(RustSolverBackend) as Box<dyn FitBackend>,
            reg,
            ServiceConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(wait_us),
            },
        );
        // Closed-loop latency (single caller — batching can't help).
        bench(&format!("single-caller latency, max_wait={wait_us}us"), 10, 200, || {
            std::hint::black_box(svc.predict("wordcount", 20, 5).unwrap());
        });
        // Open-loop burst: 512 async requests, then drain.
        let st = bench(&format!("burst of 512 requests, max_wait={wait_us}us"), 2, 10, || {
            let rxs: Vec<_> = (0..512)
                .map(|i| svc.predict_async("wordcount", 5 + (i % 36), 5).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        });
        report(
            &format!("burst throughput at max_wait={wait_us}us"),
            format!("{:.0} req/s", st.throughput(512.0)),
        );
        report(
            &format!("mean batch size at max_wait={wait_us}us"),
            format!("{:.1}", svc.metrics.mean_batch_size()),
        );
    }
}
