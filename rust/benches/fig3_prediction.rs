//! Bench: regenerate **Fig. 3** (a,b = WordCount; c,d = Exim) — actual vs
//! predicted execution time and per-experiment prediction errors on 20
//! held-out settings, plus the wall-clock cost of each pipeline stage.
//!
//! Run: `cargo bench --bench fig3_prediction`

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::model::regression::RegressionModel;
use mrtuner::profiler::{paper_campaign, CampaignExecutor};
use mrtuner::report::experiments::{default_backend, fig3};
use mrtuner::util::benchkit::{bench, report, section};

fn main() {
    for app in AppId::paper_apps() {
        section(&format!("Fig. 3 — {}", app.name()));
        let d = fig3(app, 42);
        println!(
            "{:>10} {:>12} {:>12} {:>9}",
            "(M,R)", "actual_s", "predicted_s", "error"
        );
        for (i, s) in d.test_specs.iter().enumerate() {
            println!(
                "{:>10} {:>12.1} {:>12.1} {:>8.2}%",
                format!("({},{})", s.num_mappers, s.num_reducers),
                d.errors.actual[i],
                d.errors.predicted[i],
                d.errors.errors_pct[i]
            );
        }
        report(
            &format!("{} mean error (paper: WC 0.92 / Exim 2.80)", app.name()),
            format!("{:.4}%", d.errors.mean_pct()),
        );
        report(
            &format!("{} error variance (paper: WC 2.60 / Exim 6.70)", app.name()),
            format!("{:.4}%", d.errors.variance_pct()),
        );
        report(
            &format!("{} R^2 actual-vs-predicted", app.name()),
            format!("{:.4}", d.errors.r_squared()),
        );
        report(
            &format!("{} mean error < 5% (headline)", app.name()),
            if d.errors.mean_pct() < 5.0 { "yes" } else { "NO" },
        );
    }

    section("pipeline stage timings");
    let cluster = Cluster::paper_cluster();
    let (train_c, _) = paper_campaign(AppId::WordCount, 42);
    bench("profile campaign (20 settings x 5 reps, serial)", 1, 5, || {
        std::hint::black_box(train_c.run(&cluster));
    });
    bench("profile campaign (parallel executor)", 1, 5, || {
        // Fresh executor per iteration so the rep cache stays cold.
        let exec = CampaignExecutor::machine_sized();
        std::hint::black_box(train_c.run_with(&cluster, &exec));
    });
    let (_, ds) = train_c.run(&cluster);
    let (mut backend, name) = default_backend();
    bench(&format!("fit 20-row dataset via {name}"), 2, 20, || {
        std::hint::black_box(
            RegressionModel::fit_dataset(backend.as_mut(), &ds).unwrap(),
        );
    });
    let model = RegressionModel::fit_dataset(backend.as_mut(), &ds).unwrap();
    let params: Vec<[f64; 2]> = (0..64)
        .map(|i| [5.0 + (i % 36) as f64, 5.0 + (i % 30) as f64])
        .collect();
    bench(&format!("predict 64-row batch via {name}"), 2, 50, || {
        std::hint::black_box(backend.predict(&model.coeffs, &params).unwrap());
    });
}
