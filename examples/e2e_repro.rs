//! End-to-end reproduction driver (DESIGN.md §7, EXPERIMENTS.md).
//!
//! Exercises the full stack on a real small workload: functional
//! MapReduce execution over generated corpus/mainlog bytes (outputs
//! verified against ground truth), profile calibration, the paper's
//! profiling campaigns on the simulated 4-node cluster, fitting through
//! the AOT JAX+Pallas artifact via PJRT, held-out prediction, and the
//! Fig. 4 surface spot-check — finishing with the paper's headline
//! claim (mean prediction error < 5%).
//!
//! Run with: `cargo run --release --example e2e_repro [-- --seed N]`

use mrtuner::report::e2e;
use mrtuner::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).unwrap_or_default();
    let seed = args.u64_or("seed", 42).unwrap_or(42);
    match e2e::run(seed) {
        Ok(out) => {
            println!(
                "\nsummary: wordcount {:.2}% / exim {:.2}% mean error, \
                 backend {}, surface min at (M={}, R={})",
                out.wordcount_mean_err_pct,
                out.exim_mean_err_pct,
                out.backend,
                out.surface_min.0,
                out.surface_min.1
            );
            if !out.headline_reproduced {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("e2e validation FAILED: {e}");
            std::process::exit(1);
        }
    }
}
