//! Smart scheduler: the paper's §III use case — "efficient managing of
//! incoming jobs to a cluster/cloud by making scheduler smarter".
//!
//! A queue of mixed jobs (WordCount / Exim / Grep at various settings)
//! arrives at the cluster.  We compare three policies:
//!
//! * FIFO            — arrival order (the Hadoop 0.20 default);
//! * predicted-SJF   — shortest-first by the *fitted models'* predictions,
//!                     served through the batching prediction service;
//! * oracle-SJF      — shortest-first by true (simulated) durations, the
//!                     upper bound on what prediction quality can buy.
//!
//! The gap between predicted-SJF and oracle-SJF is the cost of the ~1-3%
//! prediction error — which is the paper's pitch: errors this small make
//! model-driven scheduling nearly optimal.
//!
//! Run with: `cargo run --release --example smart_scheduler`

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::coordinator::{
    evaluate_order, fifo_order, sjf_order, JobRequest, ModelRegistry,
    PredictionService, ServiceConfig,
};
use mrtuner::model::regression::RegressionModel;
use mrtuner::mr::{run_job, JobConfig};
use mrtuner::profiler::paper_campaign;
use mrtuner::report::experiments::default_backend;
use mrtuner::util::bytes::fmt_secs;
use mrtuner::util::rng::Rng;

fn main() {
    let cluster = Cluster::paper_cluster();

    // ---- fit one model per application and install into the service.
    let mut registry = ModelRegistry::new();
    {
        let (mut backend, name) = default_backend();
        for app in AppId::all() {
            let (train, _) = paper_campaign(app, 42);
            let (_, ds) = train.run(&cluster);
            let model =
                RegressionModel::fit_dataset(backend.as_mut(), &ds).expect("fit");
            println!("fitted {} via {name}", app.name());
            registry.insert(model);
        }
    }
    let service = PredictionService::start(
        || default_backend().0,
        registry,
        ServiceConfig::default(),
    );

    // ---- a bursty queue of 12 mixed jobs.
    let mut rng = Rng::new(7);
    let apps = [AppId::WordCount, AppId::EximParse, AppId::Grep];
    let jobs: Vec<JobRequest> = (0..12)
        .map(|i| JobRequest {
            app: *rng.choice(&apps),
            num_mappers: rng.range_u64(5, 41) as u32,
            num_reducers: rng.range_u64(5, 41) as u32,
            seed: 1000 + i,
        })
        .collect();
    println!("\nqueue:");
    for (i, j) in jobs.iter().enumerate() {
        println!(
            "  [{i:>2}] {:<10} M={:<2} R={:<2}",
            j.app.name(),
            j.num_mappers,
            j.num_reducers
        );
    }

    // ---- three policies.
    let fifo = evaluate_order(&cluster, &jobs, &fifo_order(&jobs));
    let predicted = sjf_order(&jobs, |j| {
        service.predict(j.app.name(), j.num_mappers, j.num_reducers).ok()
    });
    let smart = evaluate_order(&cluster, &jobs, &predicted);
    let oracle_order = sjf_order(&jobs, |j| {
        let config = JobConfig::paper_default(j.num_mappers, j.num_reducers)
            .with_seed(j.seed);
        Some(run_job(&cluster, &j.app.profile(), &config).total_time_s)
    });
    let oracle = evaluate_order(&cluster, &jobs, &oracle_order);

    println!("\n{:<16} {:>18} {:>14}", "policy", "mean completion", "makespan");
    for (name, o) in [
        ("FIFO", &fifo),
        ("predicted-SJF", &smart),
        ("oracle-SJF", &oracle),
    ] {
        println!(
            "{:<16} {:>18} {:>14}",
            name,
            fmt_secs(o.mean_completion_s),
            fmt_secs(o.makespan_s)
        );
    }
    let gain = 100.0 * (1.0 - smart.mean_completion_s / fifo.mean_completion_s);
    let gap = 100.0 * (smart.mean_completion_s / oracle.mean_completion_s - 1.0);
    println!(
        "\npredicted-SJF cuts mean completion by {gain:.1}% vs FIFO; \
         {gap:.2}% above the oracle"
    );
    let (req, batches, mean_batch) = (
        service.metrics.requests.load(std::sync::atomic::Ordering::Relaxed),
        service.metrics.batches.load(std::sync::atomic::Ordering::Relaxed),
        service.metrics.mean_batch_size(),
    );
    println!(
        "prediction service: {req} requests in {batches} backend calls \
         (mean batch {mean_batch:.1})"
    );
}
