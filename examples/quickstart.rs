//! Quickstart: the paper's three phases in ~40 lines of API usage.
//!
//! 1. profile WordCount across (M, R) settings on the simulated 4-node
//!    cluster (5 runs per setting, averaged — paper Fig. 2a);
//! 2. fit the per-parameter-cubic regression (Eqn. 6) via the production
//!    backend (AOT JAX+Pallas artifact on PJRT when built);
//! 3. predict unseen settings (Fig. 2b) and compare against fresh runs.
//!
//! Run with: `cargo run --release --example quickstart`

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::model::regression::RegressionModel;
use mrtuner::profiler::{paper_campaign, run_experiment, ExperimentSpec};
use mrtuner::report::experiments::default_backend;
use mrtuner::util::bytes::fmt_secs;

fn main() {
    // -- 1. profiling phase -------------------------------------------
    let cluster = Cluster::paper_cluster();
    let (train_campaign, _) = paper_campaign(AppId::WordCount, 42);
    println!(
        "profiling {} settings x {} reps...",
        train_campaign.specs.len(),
        train_campaign.reps
    );
    let (_, dataset) = train_campaign.run(&cluster);

    // -- 2. modeling phase --------------------------------------------
    let (mut backend, backend_name) = default_backend();
    let model = RegressionModel::fit_dataset(backend.as_mut(), &dataset)
        .expect("fit");
    println!("fitted via {backend_name}: coefficients {:?}\n", model.coeffs);

    // -- 3. prediction phase ------------------------------------------
    println!("{:>10} {:>12} {:>12} {:>8}", "(M,R)", "predicted", "actual", "error");
    for (m, r) in [(8, 6), (18, 7), (24, 12), (33, 28), (40, 40)] {
        let predicted = model.predict_one(m, r);
        let actual = run_experiment(
            &cluster,
            &ExperimentSpec::new(AppId::WordCount, m, r),
            5,
            777, // a session seed the model has never seen
        )
        .mean_time_s;
        println!(
            "{:>10} {:>12} {:>12} {:>7.2}%",
            format!("({m},{r})"),
            fmt_secs(predicted),
            fmt_secs(actual),
            100.0 * (predicted - actual).abs() / actual
        );
    }
}
