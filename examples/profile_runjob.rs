fn main() {
    let cluster = mrtuner::cluster::Cluster::paper_cluster();
    let app = mrtuner::apps::AppId::WordCount.profile();
    let mut total = 0.0;
    for seed in 0..20000u64 {
        let config = mrtuner::mr::JobConfig::paper_default(20, 5).with_seed(seed);
        total += mrtuner::mr::run_job(&cluster, &app, &config).total_time_s;
    }
    println!("{total}");
}
