//! Capacity planner: use the fitted model to answer the questions the
//! paper's introduction motivates — "cloud customers and providers
//! approximate the total execution time a MapReduce application needs in
//! order to make scheduling jobs smarter" (§V.B).
//!
//! Given an SLA deadline, sweep the full (M, R) configuration space
//! *through the model* (1296 predictions served by the batched PJRT
//! predict artifact — no cluster time burned), then validate the chosen
//! configuration with real simulated runs.
//!
//! Run with: `cargo run --release --example capacity_planner`

use mrtuner::apps::AppId;
use mrtuner::cluster::Cluster;
use mrtuner::model::regression::RegressionModel;
use mrtuner::profiler::{paper_campaign, run_experiment, ExperimentSpec};
use mrtuner::report::experiments::default_backend;
use mrtuner::util::bytes::fmt_secs;

fn main() {
    let deadline_s = 640.0;
    let app = AppId::WordCount;
    let cluster = Cluster::paper_cluster();

    // Fit the model once from a profiling campaign.
    let (train, _) = paper_campaign(app, 42);
    println!("profiling {} ({} settings x 5 reps)...", app.name(), train.specs.len());
    let (_, ds) = train.run(&cluster);
    let (mut backend, name) = default_backend();
    let model = RegressionModel::fit_dataset(backend.as_mut(), &ds).expect("fit");

    // Sweep every configuration through the model (batched predict).
    let mut grid: Vec<[f64; 2]> = Vec::new();
    for m in 5..=40u32 {
        for r in 5..=40u32 {
            grid.push([m as f64, r as f64]);
        }
    }
    let preds = backend.predict(&model.coeffs, &grid).expect("predict");
    println!(
        "swept {} configurations through the {name} backend\n",
        grid.len()
    );

    // Best configuration + all deadline-feasible ones.
    let mut order: Vec<usize> = (0..grid.len()).collect();
    order.sort_by(|&a, &b| preds[a].partial_cmp(&preds[b]).unwrap());
    let feasible = order.iter().filter(|&&i| preds[i] <= deadline_s).count();
    println!(
        "deadline {}: {} / {} configurations predicted feasible",
        fmt_secs(deadline_s),
        feasible,
        grid.len()
    );
    println!("\ntop-5 predicted configurations:");
    println!("{:>10} {:>12}", "(M,R)", "predicted");
    for &i in order.iter().take(5) {
        println!(
            "{:>10} {:>12}",
            format!("({},{})", grid[i][0] as u32, grid[i][1] as u32),
            fmt_secs(preds[i])
        );
    }

    // Validate the chosen plan against reality (fresh seeds).
    let best = order[0];
    let (bm, br) = (grid[best][0] as u32, grid[best][1] as u32);
    let actual = run_experiment(
        &cluster,
        &ExperimentSpec::new(app, bm, br),
        5,
        20_260_710,
    )
    .mean_time_s;
    println!(
        "\nchosen (M={bm}, R={br}): predicted {}, measured {} ({})",
        fmt_secs(preds[best]),
        fmt_secs(actual),
        if actual <= deadline_s { "meets deadline" } else { "MISSES deadline" },
    );
}
