"""Make `compile.*` importable whether pytest runs from `python/` or the
workspace root (`pytest python/tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
