"""AOT exporter: lower the L2 model to HLO text artifacts for the Rust side.

Usage (from ``python/``):

    python -m compile.aot --out-dir ../artifacts [--check] [--analyze]

Emits ``fit.hlo.txt``, ``predict.hlo.txt`` and ``manifest.json`` (shapes +
constants the Rust runtime asserts against).

Interchange is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 Rust crate binds) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and round-trips
cleanly.  Lowered with ``return_tuple=True``; the Rust side unwraps with
``to_tuple1()``.
"""

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import NUM_FEATURES, PARAM_SCALE, ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    fit = jax.jit(model.fit_fn).lower(*model.fit_shapes())
    predict = jax.jit(model.predict_fn).lower(*model.predict_shapes())
    return {"fit": fit, "predict": predict}


def manifest() -> dict:
    return {
        "num_features": NUM_FEATURES,
        "param_scale": PARAM_SCALE,
        "fit_rows": model.FIT_ROWS,
        "predict_rows": model.PREDICT_ROWS,
        "ridge_rel": model.RIDGE_REL,
        "dtype": "f64",
        "artifacts": {"fit": "fit.hlo.txt", "predict": "predict.hlo.txt"},
    }


def check() -> None:
    """Validate the jitted fns against the pure-jnp oracle on random data."""
    rng = np.random.default_rng(0)
    m = model.FIT_ROWS
    params = rng.integers(5, 41, size=(m, 2)).astype(np.float64)
    # Synthetic ground truth: a cubic surface + noise, like the paper's data.
    t = (
        120.0
        + 3.0 * params[:, 0]
        - 0.04 * params[:, 0] ** 2
        + 1.5 * params[:, 1]
        + rng.normal(0, 0.5, size=m)
    )
    w = np.ones(m)
    w[50:] = 0.0  # exercise padding
    coeffs = jax.jit(model.fit_fn)(params, t, w)[0]
    coeffs_ref = ref.fit(params[:50], t[:50], w[:50])
    np.testing.assert_allclose(coeffs, coeffs_ref, rtol=1e-8)
    preds = jax.jit(model.predict_fn)(coeffs, params)[0]
    np.testing.assert_allclose(preds, ref.predict(coeffs_ref, params), rtol=1e-8)
    err = np.abs(preds[:50] - t[:50]) / t[:50]
    print(f"check OK: mean in-sample error {100 * err.mean():.3f}%")


def analyze(lowered_map) -> None:
    """Structure-level perf report (see DESIGN.md §Perf, L1/L2)."""
    for name, lowered in lowered_map.items():
        hlo = lowered.compiler_ir("hlo")
        text = hlo.as_hlo_text() if hasattr(hlo, "as_hlo_text") else str(hlo)
        ops = [l.strip() for l in text.splitlines() if "=" in l and "(" in l]
        dots = sum("dot(" in l or " dot " in l for l in ops)
        print(f"[analyze] {name}: {len(ops)} HLO ops, {dots} dot ops")
    bm, f = 64, NUM_FEATURES
    vmem = bm * f * 8 + 2 * bm * 8 + f * f * 8 + f * 8
    print(
        f"[analyze] gram kernel VMEM/block: {vmem} B "
        f"({vmem / 2**20:.4f} MiB of ~16 MiB) — launch-latency bound at "
        f"paper scale; MXU tile (8x128) padded from (7, {bm})"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--analyze", action="store_true")
    args = ap.parse_args()

    if args.check:
        check()

    lowered = lower_all()
    if args.analyze:
        analyze(lowered)

    os.makedirs(args.out_dir, exist_ok=True)
    for name, low in lowered.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(low)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
