"""Cubic polynomial feature expansion as a Pallas kernel.

The paper (Eqn. 2) builds the design matrix

    P[k, :] = [1, p1, p1^2, p1^3, ..., pN, pN^2, pN^3]

for N configuration parameters.  Here N = 2 (number of mappers, number of
reducers), so each row expands to F = 1 + 3N = 7 features.

Parameters are normalized by ``PARAM_SCALE`` (the paper's maximum setting,
40) before expansion: raw mapper/reducer counts cubed reach 6.4e4 and the
Gram matrix of the *raw* cubic basis is catastrophically ill-conditioned
even in f64.  The same normalization is baked into the predict path, so the
coefficient vector is internally consistent and callers never see it.

TPU shaping: the row dimension is tiled into VMEM-resident blocks of
``block_rows``; each grid step reads a ``(block_rows, 2)`` tile and writes a
``(block_rows, 7)`` tile.  The expansion is pure VPU element-wise work
(powers via multiplies, no transcendentals).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Number of regression features: intercept + 3 powers for each of 2 params.
NUM_FEATURES = 7

#: Normalization constant for mapper/reducer counts (paper range is 5..40).
PARAM_SCALE = 40.0


def _poly_features_kernel(p_ref, out_ref):
    """One row-block: expand normalized params into the cubic basis."""
    p = p_ref[...] / PARAM_SCALE  # (bm, 2)
    p1 = p[:, 0]
    p2 = p[:, 1]
    p1sq = p1 * p1
    p2sq = p2 * p2
    out_ref[...] = jnp.stack(
        [jnp.ones_like(p1), p1, p1sq, p1sq * p1, p2, p2sq, p2sq * p2],
        axis=1,
    )


@functools.partial(jax.jit, static_argnames=("block_rows",))
def poly_features(params, *, block_rows=64):
    """Expand ``params`` of shape (M, 2) into the (M, 7) cubic design matrix.

    ``M`` must be a multiple of ``block_rows`` (callers pad; the AOT shapes
    are fixed at M = 64).  dtype follows the input (f64 on the AOT path).
    """
    m, n = params.shape
    if n != 2:
        raise ValueError(f"expected 2 configuration parameters, got {n}")
    if m % block_rows != 0:
        raise ValueError(f"rows {m} not a multiple of block_rows {block_rows}")
    grid = (m // block_rows,)
    return pl.pallas_call(
        _poly_features_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, 2), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, NUM_FEATURES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, NUM_FEATURES), params.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(params)
