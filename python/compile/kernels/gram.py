"""Fused weighted normal-equation assembly as a Pallas kernel.

Given the design matrix X (M, F), per-experiment weights w (M,) and observed
execution times t (M,), the paper's least-squares step (Eqn. 6) needs

    G = Xᵀ diag(w) X          (F, F)   the weighted Gram matrix
    b = Xᵀ (w ⊙ t)            (F,)     the weighted moment vector

Weights implement both the paper's "mean of five runs" protocol (reps can be
folded in as fractional weights) and the zero-padding of training sets
smaller than the fixed AOT shape: a padded row with w = 0 contributes
exactly nothing, which `python/tests/test_model.py` property-tests.

TPU shaping: the grid walks row blocks of size ``block_rows``; each step
loads an (bm, F) tile of X plus (bm,) tiles of w and t into VMEM and
accumulates the rank-bm update into the (F, F) output block, which Pallas
keeps resident in VMEM across the whole grid (output revisiting).  The
per-block update is an MXU-shaped  (F, bm) @ (bm, F)  contraction.  The
first grid step zero-initializes the accumulators via ``pl.when``.

G and b are accumulated in one pass over X — fusing them halves HBM traffic
versus two separate contractions (X is read once).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .poly_features import NUM_FEATURES


def _gram_kernel(x_ref, w_ref, t_ref, g_ref, b_ref):
    """Accumulate one row-block's contribution to G and b."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    x = x_ref[...]            # (bm, F)
    w = w_ref[...]            # (bm,)
    t = t_ref[...]            # (bm,)
    xw = x * w[:, None]       # (bm, F) — weight folded into the left operand
    # MXU contraction: (F, bm) @ (bm, F) -> (F, F)
    g_ref[...] += jnp.dot(xw.T, x, preferred_element_type=x.dtype)
    b_ref[...] += jnp.dot(xw.T, t, preferred_element_type=x.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def gram_system(x, w, t, *, block_rows=64):
    """Return ``(G, b)`` for the weighted normal equations.

    ``x``: (M, F) design matrix; ``w``: (M,) weights; ``t``: (M,) targets.
    M must be a multiple of ``block_rows``.
    """
    m, f = x.shape
    if f != NUM_FEATURES:
        raise ValueError(f"expected {NUM_FEATURES} features, got {f}")
    if w.shape != (m,) or t.shape != (m,):
        raise ValueError("w and t must be (M,) matching x rows")
    if m % block_rows != 0:
        raise ValueError(f"rows {m} not a multiple of block_rows {block_rows}")
    grid = (m // block_rows,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, f), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((f, f), lambda i: (0, 0)),  # VMEM-resident accumulator
            pl.BlockSpec((f,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((f, f), x.dtype),
            jax.ShapeDtypeStruct((f,), x.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, t)
