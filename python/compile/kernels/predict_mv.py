"""Batched prediction (design-matrix · coefficients) as a Pallas kernel.

The prediction phase (paper Fig. 2b / Eqn. 5) evaluates

    T̂[k] = features(p[k]) · A

for a batch of configuration-parameter rows.  The Rust coordinator batches
concurrent prediction requests up to the fixed AOT batch (64) and issues a
single PJRT execution, so this matvec is the request-path hot spot.

TPU shaping: row blocks of the feature matrix stream through VMEM; the
coefficient vector (F = 7 values) stays resident.  Each grid step is a
(bm, F) @ (F,) VPU/MXU contraction producing a (bm,) output tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .poly_features import NUM_FEATURES


def _predict_kernel(x_ref, a_ref, out_ref):
    out_ref[...] = jnp.dot(
        x_ref[...], a_ref[...], preferred_element_type=x_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_rows",))
def predict_mv(x, coeffs, *, block_rows=64):
    """Return ``x @ coeffs`` for a (K, F) feature matrix, row-block tiled."""
    k, f = x.shape
    if f != NUM_FEATURES:
        raise ValueError(f"expected {NUM_FEATURES} features, got {f}")
    if coeffs.shape != (f,):
        raise ValueError(f"coeffs must be ({f},), got {coeffs.shape}")
    if k % block_rows != 0:
        raise ValueError(f"rows {k} not a multiple of block_rows {block_rows}")
    grid = (k // block_rows,)
    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, f), lambda i: (i, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((k,), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, coeffs)
