"""Pure-jnp correctness oracles for the Pallas kernels.

These implement the paper's math (Eqns. 2, 5, 6) directly with jax.numpy
and are the ground truth the kernels are tested against (pytest +
hypothesis in ``python/tests/``).  They are also used by ``aot.py --check``
to validate the lowered artifacts end to end.
"""

import jax.numpy as jnp

from .poly_features import NUM_FEATURES, PARAM_SCALE


def poly_features(params):
    """(M, 2) raw mapper/reducer counts -> (M, 7) normalized cubic basis."""
    p = params / PARAM_SCALE
    p1, p2 = p[:, 0], p[:, 1]
    return jnp.stack(
        [jnp.ones_like(p1), p1, p1**2, p1**3, p2, p2**2, p2**3], axis=1
    )


def gram_system(x, w, t):
    """Weighted normal-equation system: G = XᵀWX, b = Xᵀ(w·t)."""
    xw = x * w[:, None]
    return xw.T @ x, xw.T @ t


def fit(params, times, weights, ridge_rel=1e-9):
    """Full fit oracle: params -> coefficient vector (Eqn. 6 + ridge)."""
    x = poly_features(params)
    g, b = gram_system(x, weights, times)
    lam = ridge_rel * jnp.trace(g) / NUM_FEATURES
    g = g + lam * jnp.eye(NUM_FEATURES, dtype=x.dtype)
    return jnp.linalg.solve(g, b)


def predict(coeffs, params):
    """Prediction oracle (Eqn. 5) for raw (K, 2) parameter rows."""
    return poly_features(params) @ coeffs
