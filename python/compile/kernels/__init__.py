"""Layer-1 Pallas kernels for the mrtuner regression hot path.

All kernels are authored for TPU-shaped execution (row-block tiling into
VMEM, Gram accumulation in scratch) but are lowered with ``interpret=True``
so the resulting HLO runs on any PJRT backend, including the Rust CPU
client on the request path.  Correctness oracles live in ``ref.py``.
"""

from .poly_features import poly_features, NUM_FEATURES, PARAM_SCALE
from .gram import gram_system
from .predict_mv import predict_mv
from . import ref

__all__ = [
    "poly_features",
    "gram_system",
    "predict_mv",
    "ref",
    "NUM_FEATURES",
    "PARAM_SCALE",
]
