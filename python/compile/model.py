"""Layer-2 JAX model: the paper's fit and predict computations.

Composes the Layer-1 Pallas kernels into the two request-path computations
the Rust coordinator executes via PJRT:

* ``fit_fn``     — profiling/modeling phase (paper Fig. 2a, Eqn. 6):
                   weighted cubic-basis least squares with a relative ridge.
* ``predict_fn`` — prediction phase (paper Fig. 2b, Eqn. 5).

Both are pure f64 functions with fixed AOT shapes (see ``aot.py``); Python
is never on the request path — these lower once to HLO text.
"""

import jax
import jax.numpy as jnp

from .kernels import poly_features, gram_system, predict_mv, NUM_FEATURES

jax.config.update("jax_enable_x64", True)

#: Fixed AOT row counts.  Training sets / prediction batches are padded to
#: these by the Rust side (weights make padding exact for fit; the batcher
#: slices real rows for predict).
FIT_ROWS = 64
PREDICT_ROWS = 64

#: Relative ridge: lambda = RIDGE_REL * trace(G)/F.  Guards degenerate
#: training grids (e.g. all experiments sharing one mapper count) without
#: measurably biasing well-posed fits (ablated in rust/benches/ablation.rs).
RIDGE_REL = 1e-9


def _cholesky_solve(g, b):
    """Unrolled Cholesky solve for the fixed F x F normal equations.

    ``jnp.linalg.solve`` lowers to a LAPACK custom-call using the typed-FFI
    API (version 4), which the xla_extension 0.5.1 runtime behind the Rust
    ``xla`` crate rejects at compile time.  For F = 7 a statically unrolled
    Cholesky factorization in plain jnp ops lowers to pure HLO
    (mul/sub/div/sqrt + gathers) and runs everywhere.  The op count is
    O(F^3/3) ~ 110 fused scalar ops — negligible next to the Gram kernel.
    """
    f = NUM_FEATURES
    # Factor: L lower-triangular with G = L Lᵀ, computed into a dict of
    # scalars (static indices unroll at trace time).
    l = {}
    for i in range(f):
        for j in range(i + 1):
            s = g[i, j]
            for k in range(j):
                s = s - l[(i, k)] * l[(j, k)]
            if i == j:
                l[(i, j)] = jnp.sqrt(s)
            else:
                l[(i, j)] = s / l[(j, j)]
    # Forward substitution L y = b.
    y = []
    for i in range(f):
        s = b[i]
        for k in range(i):
            s = s - l[(i, k)] * y[k]
        y.append(s / l[(i, i)])
    # Back substitution Lᵀ a = y.
    a = [None] * f
    for i in reversed(range(f)):
        s = y[i]
        for k in range(i + 1, f):
            s = s - l[(k, i)] * a[k]
        a[i] = s / l[(i, i)]
    return jnp.stack(a)


def fit_fn(params, times, weights):
    """Solve the weighted normal equations for the cubic coefficient vector.

    params:  f64[FIT_ROWS, 2]  raw (num_mappers, num_reducers) rows
    times:   f64[FIT_ROWS]     profiled mean execution times (seconds)
    weights: f64[FIT_ROWS]     >= 0; 0 marks padding rows
    returns: f64[NUM_FEATURES] coefficients over the normalized cubic basis
    """
    x = poly_features(params)
    g, b = gram_system(x, weights, times)
    lam = RIDGE_REL * jnp.trace(g) / NUM_FEATURES
    g = g + lam * jnp.eye(NUM_FEATURES, dtype=x.dtype)
    # F = 7: direct dense solve; the Gram assembly above is the part that
    # scales with profiled-experiment count, not this.
    return (_cholesky_solve(g, b),)


def predict_fn(coeffs, params):
    """Evaluate the fitted model on a batch of parameter rows.

    coeffs: f64[NUM_FEATURES]
    params: f64[PREDICT_ROWS, 2] raw (num_mappers, num_reducers) rows
    returns: f64[PREDICT_ROWS]   predicted execution times (seconds)
    """
    x = poly_features(params)
    return (predict_mv(x, coeffs),)


def fit_shapes():
    f64 = jnp.float64
    return (
        jax.ShapeDtypeStruct((FIT_ROWS, 2), f64),
        jax.ShapeDtypeStruct((FIT_ROWS,), f64),
        jax.ShapeDtypeStruct((FIT_ROWS,), f64),
    )


def predict_shapes():
    f64 = jnp.float64
    return (
        jax.ShapeDtypeStruct((NUM_FEATURES,), f64),
        jax.ShapeDtypeStruct((PREDICT_ROWS, 2), f64),
    )
