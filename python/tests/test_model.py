"""L2 model correctness: fit/predict round trips, padding, conditioning."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from compile import model
from compile.kernels import NUM_FEATURES, PARAM_SCALE, ref

jax.config.update("jax_enable_x64", True)

hypothesis.settings.register_profile(
    "model", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("model")

FIT = jax.jit(model.fit_fn)
PREDICT = jax.jit(model.predict_fn)


def paper_grid(rng, n):
    """Random (M, R) settings in the paper's 5..40 range."""
    return rng.integers(5, 41, size=(n, 2)).astype(np.float64)


def cubic_surface(params, rng=None, noise=0.0):
    """A ground-truth surface inside the model family."""
    p = params / PARAM_SCALE
    t = (
        200.0
        - 150.0 * p[:, 0]
        + 180.0 * p[:, 0] ** 2
        - 60.0 * p[:, 0] ** 3
        + 40.0 * p[:, 1]
        + 25.0 * p[:, 1] ** 2
    )
    if noise and rng is not None:
        t = t + rng.normal(0, noise, size=len(t))
    return t


def padded(params, times, n):
    m = model.FIT_ROWS
    p = np.zeros((m, 2))
    t = np.zeros(m)
    w = np.zeros(m)
    p[:n], t[:n], w[:n] = params[:n], times[:n], 1.0
    return jnp.asarray(p), jnp.asarray(t), jnp.asarray(w)


class TestFit:
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(20, 64))
    def test_recovers_in_family_surface(self, seed, n):
        """Noise-free data from the model family is fit almost exactly.

        Tolerance is bounded by the relative ridge (RIDGE_REL * trace/F
        against a Gram eigenvalue spread of ~1e5), not by f64 precision.
        """
        rng = np.random.default_rng(seed)
        params = paper_grid(rng, n)
        times = cubic_surface(params)
        p, t, w = padded(params, times, n)
        (coeffs,) = FIT(p, t, w)
        preds = ref.predict(coeffs, jnp.asarray(params))
        np.testing.assert_allclose(preds, times, rtol=1e-5)

    @given(seed=st.integers(0, 2**31 - 1))
    def test_matches_oracle(self, seed):
        rng = np.random.default_rng(seed)
        params = paper_grid(rng, 64)
        times = cubic_surface(params, rng, noise=5.0)
        w = jnp.ones(64)
        (coeffs,) = FIT(jnp.asarray(params), jnp.asarray(times), w)
        want = ref.fit(jnp.asarray(params), jnp.asarray(times), jnp.ones(64))
        np.testing.assert_allclose(coeffs, want, rtol=1e-9)

    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(10, 63))
    def test_padding_invariance(self, seed, n):
        """Garbage beyond the weight mask must not affect the fit."""
        rng = np.random.default_rng(seed)
        params = paper_grid(rng, n)
        times = cubic_surface(params, rng, noise=2.0)
        p1, t1, w = padded(params, times, n)
        # Same live rows, different garbage in the padding area.
        p2 = np.asarray(p1).copy()
        t2 = np.asarray(t1).copy()
        p2[n:] = rng.uniform(1, 100, size=(model.FIT_ROWS - n, 2))
        t2[n:] = rng.uniform(1, 1e6, size=model.FIT_ROWS - n)
        (c1,) = FIT(p1, t1, w)
        (c2,) = FIT(jnp.asarray(p2), jnp.asarray(t2), w)
        np.testing.assert_allclose(c1, c2, rtol=1e-9, atol=1e-9)

    def test_weighted_repetitions_equal_mean(self):
        """5 repeated runs with weight 1 == 1 averaged run with weight 5.

        This is the paper's 'mean of five executions' protocol expressed
        through the weight vector.
        """
        rng = np.random.default_rng(11)
        params = paper_grid(rng, 12)
        base = cubic_surface(params)
        reps = np.stack([base + rng.normal(0, 3.0, 12) for _ in range(5)])

        # (a) all 60 rows individually
        p_all = np.tile(params, (5, 1))
        t_all = reps.reshape(-1)
        pa, ta, wa = padded(p_all, t_all, 60)
        (ca,) = FIT(pa, ta, wa)

        # (b) 12 averaged rows, weight 5
        pb, tb, wb = padded(params, reps.mean(axis=0), 12)
        wb = jnp.asarray(np.where(np.asarray(wb) > 0, 5.0, 0.0))
        (cb,) = FIT(pb, tb, wb)
        np.testing.assert_allclose(ca, cb, rtol=1e-8, atol=1e-10)

    def test_degenerate_grid_does_not_blow_up(self):
        """All experiments share one mapper count -> rank-deficient Gram.

        The relative ridge must keep the solve finite (predictions sane on
        the training rows themselves).
        """
        rng = np.random.default_rng(5)
        params = np.column_stack(
            [np.full(30, 20.0), rng.integers(5, 41, 30)]
        ).astype(np.float64)
        times = cubic_surface(params, rng, noise=1.0)
        p, t, w = padded(params, times, 30)
        (coeffs,) = FIT(p, t, w)
        assert np.all(np.isfinite(np.asarray(coeffs)))
        preds = ref.predict(coeffs, jnp.asarray(params))
        err = np.abs(np.asarray(preds) - times) / times
        assert err.mean() < 0.05

    def test_all_zero_weights_finite(self):
        p = jnp.zeros((model.FIT_ROWS, 2))
        t = jnp.zeros(model.FIT_ROWS)
        w = jnp.zeros(model.FIT_ROWS)
        (coeffs,) = FIT(p, t, w)
        # Singular system; ridge of 0 trace gives 0 lambda -> solve of a
        # zero matrix.  We only require no crash and a defined output shape.
        assert coeffs.shape == (NUM_FEATURES,)


class TestPredict:
    @given(seed=st.integers(0, 2**31 - 1))
    def test_matches_oracle(self, seed):
        rng = np.random.default_rng(seed)
        coeffs = jnp.asarray(rng.normal(size=NUM_FEATURES))
        params = jnp.asarray(paper_grid(rng, model.PREDICT_ROWS))
        (got,) = PREDICT(coeffs, params)
        np.testing.assert_allclose(got, ref.predict(coeffs, params), rtol=1e-12)

    def test_prediction_error_band_on_noisy_surface(self):
        """End-to-end paper protocol on synthetic data: error well under 5%."""
        rng = np.random.default_rng(42)
        train = paper_grid(rng, 20)
        t_train = np.stack(
            [cubic_surface(train, rng, noise=2.0) for _ in range(5)]
        ).mean(axis=0)
        p, t, w = padded(train, t_train, 20)
        (coeffs,) = FIT(p, t, w)

        test = paper_grid(rng, 20)
        truth = cubic_surface(test)
        pp = np.zeros((model.PREDICT_ROWS, 2))
        pp[:20] = test
        (preds,) = PREDICT(coeffs, jnp.asarray(pp))
        err = np.abs(np.asarray(preds)[:20] - truth) / truth
        assert err.mean() < 0.05, f"mean error {err.mean():.4f}"
