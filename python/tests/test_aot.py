"""AOT exporter tests: HLO text artifacts exist, parse, and stay consistent."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import NUM_FEATURES, ref

jax.config.update("jax_enable_x64", True)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_lower_all_produces_hlo_text(self):
        lowered = aot.lower_all()
        assert set(lowered) == {"fit", "predict"}
        for name, low in lowered.items():
            text = aot.to_hlo_text(low)
            assert text.startswith("HloModule"), name
            # 64-bit-id proto issue is avoided by text interchange; the text
            # itself must contain the f64 root types we promised the Rust side.
            assert "f64" in text, name

    def test_fit_hlo_has_expected_shapes(self):
        text = aot.to_hlo_text(aot.lower_all()["fit"])
        assert f"f64[{model.FIT_ROWS},2]" in text
        assert f"f64[{NUM_FEATURES}]" in text

    def test_predict_hlo_has_expected_shapes(self):
        text = aot.to_hlo_text(aot.lower_all()["predict"])
        assert f"f64[{model.PREDICT_ROWS},2]" in text
        assert f"f64[{model.PREDICT_ROWS}]" in text

    def test_manifest_contents(self):
        m = aot.manifest()
        assert m["num_features"] == NUM_FEATURES
        assert m["fit_rows"] == model.FIT_ROWS
        assert m["predict_rows"] == model.PREDICT_ROWS
        assert m["dtype"] == "f64"
        assert m["artifacts"] == {
            "fit": "fit.hlo.txt",
            "predict": "predict.hlo.txt",
        }

    def test_check_passes(self):
        aot.check()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    """Validate whatever is in artifacts/ — the files Rust will load."""

    def test_manifest_matches_code(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            m = json.load(f)
        assert m == aot.manifest()

    def test_artifact_files_exist_and_are_hlo(self):
        for name in ("fit.hlo.txt", "predict.hlo.txt"):
            path = os.path.join(ART, name)
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), path

    def test_artifacts_reproducible(self):
        """Re-lowering today must match the files on disk (determinism)."""
        lowered = aot.lower_all()
        for name in ("fit", "predict"):
            with open(os.path.join(ART, f"{name}.hlo.txt")) as f:
                on_disk = f.read()
            assert aot.to_hlo_text(lowered[name]) == on_disk
