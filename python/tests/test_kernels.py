"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and value ranges; every kernel must match
``ref.py`` to tight tolerances (exact structural math, so rtol is small).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import (
    NUM_FEATURES,
    PARAM_SCALE,
    gram_system,
    poly_features,
    predict_mv,
    ref,
)

jax.config.update("jax_enable_x64", True)

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")

DTYPES = [jnp.float32, jnp.float64]
RTOL = {jnp.float32: 2e-5, jnp.float64: 1e-12}


def rand_params(rng, rows, dtype, lo=1.0, hi=64.0):
    return jnp.asarray(
        rng.uniform(lo, hi, size=(rows, 2)), dtype=dtype
    )


# ---------------------------------------------------------------- features

class TestPolyFeatures:
    @given(
        blocks=st.integers(1, 4),
        block_rows=st.sampled_from([8, 16, 64]),
        dtype=st.sampled_from(DTYPES),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, blocks, block_rows, dtype, seed):
        rng = np.random.default_rng(seed)
        params = rand_params(rng, blocks * block_rows, dtype)
        got = poly_features(params, block_rows=block_rows)
        want = ref.poly_features(params)
        np.testing.assert_allclose(got, want, rtol=RTOL[dtype])
        assert got.dtype == dtype
        assert got.shape == (blocks * block_rows, NUM_FEATURES)

    def test_intercept_column_is_one(self):
        rng = np.random.default_rng(0)
        params = rand_params(rng, 64, jnp.float64)
        feats = poly_features(params)
        np.testing.assert_array_equal(feats[:, 0], np.ones(64))

    def test_normalization_scale(self):
        """A row at the scale boundary maps to basis value exactly 1."""
        params = jnp.full((64, 2), PARAM_SCALE, dtype=jnp.float64)
        feats = poly_features(params)
        np.testing.assert_allclose(feats, np.ones((64, NUM_FEATURES)))

    def test_power_structure(self):
        """Columns 2,3 (and 5,6) are exact squares/cubes of columns 1 (4)."""
        rng = np.random.default_rng(1)
        params = rand_params(rng, 64, jnp.float64)
        f = np.asarray(poly_features(params))
        np.testing.assert_allclose(f[:, 2], f[:, 1] ** 2, rtol=1e-14)
        np.testing.assert_allclose(f[:, 3], f[:, 1] ** 3, rtol=1e-14)
        np.testing.assert_allclose(f[:, 5], f[:, 4] ** 2, rtol=1e-14)
        np.testing.assert_allclose(f[:, 6], f[:, 4] ** 3, rtol=1e-14)

    def test_rejects_bad_param_count(self):
        with pytest.raises(ValueError, match="2 configuration parameters"):
            poly_features(jnp.ones((64, 3)))

    def test_rejects_unaligned_rows(self):
        with pytest.raises(ValueError, match="multiple of block_rows"):
            poly_features(jnp.ones((63, 2)))


# -------------------------------------------------------------------- gram

class TestGramSystem:
    @given(
        blocks=st.integers(1, 4),
        block_rows=st.sampled_from([8, 32, 64]),
        dtype=st.sampled_from(DTYPES),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, blocks, block_rows, dtype, seed):
        rng = np.random.default_rng(seed)
        m = blocks * block_rows
        x = jnp.asarray(rng.normal(size=(m, NUM_FEATURES)), dtype=dtype)
        w = jnp.asarray(rng.uniform(0, 2, size=m), dtype=dtype)
        t = jnp.asarray(rng.uniform(10, 1000, size=m), dtype=dtype)
        g, b = gram_system(x, w, t, block_rows=block_rows)
        g_ref, b_ref = ref.gram_system(x, w, t)
        np.testing.assert_allclose(g, g_ref, rtol=RTOL[dtype], atol=1e-6)
        np.testing.assert_allclose(b, b_ref, rtol=RTOL[dtype], atol=1e-6)

    @given(seed=st.integers(0, 2**31 - 1))
    def test_gram_is_symmetric_psd(self, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(64, NUM_FEATURES)))
        w = jnp.asarray(rng.uniform(0, 1, size=64))
        t = jnp.asarray(rng.uniform(size=64))
        g, _ = gram_system(x, w, t)
        g = np.asarray(g)
        np.testing.assert_allclose(g, g.T, rtol=1e-12)
        eig = np.linalg.eigvalsh(g)
        assert eig.min() >= -1e-9 * max(1.0, eig.max())

    @given(seed=st.integers(0, 2**31 - 1), pad=st.integers(0, 63))
    def test_zero_weight_rows_contribute_nothing(self, seed, pad):
        """Padding invariance — the property the Rust fitter relies on."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(64, NUM_FEATURES)))
        t = jnp.asarray(rng.uniform(10, 100, size=64))
        w = np.ones(64)
        w[64 - pad:] = 0.0
        g_pad, b_pad = gram_system(x, jnp.asarray(w), t)
        live = 64 - pad
        g_ref, b_ref = ref.gram_system(x[:live], jnp.ones(live), t[:live])
        np.testing.assert_allclose(g_pad, g_ref, rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(b_pad, b_ref, rtol=1e-12, atol=1e-12)

    def test_single_vs_multi_block_identical(self):
        """Grid decomposition must not change the result (accumulation)."""
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(64, NUM_FEATURES)))
        w = jnp.asarray(rng.uniform(size=64))
        t = jnp.asarray(rng.uniform(size=64))
        g1, b1 = gram_system(x, w, t, block_rows=64)
        g8, b8 = gram_system(x, w, t, block_rows=8)
        np.testing.assert_allclose(g1, g8, rtol=1e-12)
        np.testing.assert_allclose(b1, b8, rtol=1e-12)

    def test_rejects_bad_shapes(self):
        x = jnp.ones((64, NUM_FEATURES))
        with pytest.raises(ValueError, match="must be \\(M,\\)"):
            gram_system(x, jnp.ones(32), jnp.ones(64))
        with pytest.raises(ValueError, match="features"):
            gram_system(jnp.ones((64, 5)), jnp.ones(64), jnp.ones(64))


# ----------------------------------------------------------------- predict

class TestPredictMv:
    @given(
        blocks=st.integers(1, 4),
        block_rows=st.sampled_from([8, 64]),
        dtype=st.sampled_from(DTYPES),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, blocks, block_rows, dtype, seed):
        rng = np.random.default_rng(seed)
        k = blocks * block_rows
        x = jnp.asarray(rng.normal(size=(k, NUM_FEATURES)), dtype=dtype)
        a = jnp.asarray(rng.normal(size=NUM_FEATURES), dtype=dtype)
        got = predict_mv(x, a, block_rows=block_rows)
        np.testing.assert_allclose(got, x @ a, rtol=RTOL[dtype], atol=1e-6)

    def test_linearity(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(64, NUM_FEATURES)))
        a1 = jnp.asarray(rng.normal(size=NUM_FEATURES))
        a2 = jnp.asarray(rng.normal(size=NUM_FEATURES))
        lhs = predict_mv(x, a1 + a2)
        rhs = predict_mv(x, a1) + predict_mv(x, a2)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-12)

    def test_rejects_bad_coeffs(self):
        with pytest.raises(ValueError, match="coeffs"):
            predict_mv(jnp.ones((64, NUM_FEATURES)), jnp.ones(5))
